//! Integration tests across the whole stack: runtime + manifest + data +
//! coordinator.  They run against the NATIVE backend and the built-in
//! manifest, so they execute on every clean checkout — no artifacts, no
//! Python.  Small batches keep the conv compute cheap.
//!
//! The PJRT mirror of the gradient-equivalence test lives behind the
//! `pjrt` feature at the bottom of this file.

use sfl_ga::coordinator::{AllocPolicy, SchemeKind, TrainConfig, Trainer};
use sfl_ga::data::init::init_params;
use sfl_ga::data::{Batcher, generate};
use sfl_ga::model::Manifest;
use sfl_ga::runtime::ModelRuntime;
use sfl_ga::tensor;

/// Built-in manifest with test-sized batches (train 8, eval 32).
fn manifest() -> Manifest {
    Manifest::builtin_with_batches(8, 32)
}

/// Small-but-real training config: 64 test samples, 48 per client.
fn test_cfg(scheme: SchemeKind, num_clients: usize, rounds: usize) -> TrainConfig {
    TrainConfig {
        scheme,
        num_clients,
        rounds,
        eval_every: rounds,
        samples_per_client: 48,
        test_samples: 64,
        alloc: AllocPolicy::Equal,
        ..Default::default()
    }
}

/// Mirror of python's split-equivalence test, through the native backend:
/// client_fwd ∘ server_grad ∘ client_grad must equal full_grad at every
/// cut point.  This is the invariant that makes split training "the same
/// computation" as centralized training (paper eq 6 vs eq 19 discussion).
#[test]
fn split_gradients_equal_full_through_native() {
    let manifest = manifest();
    let rt = ModelRuntime::native(&manifest, "mnist").unwrap();
    let spec = rt.spec().clone();
    let params = init_params(&spec, 42);
    let ds = generate(&spec, "mnist", 64, 9);
    let idx: Vec<usize> = (0..spec.train_batch).collect();
    let (x, y) = ds.batch(&idx);

    let (loss_full, g_full) = rt.full_grad(&params, &x, &y).unwrap();
    assert!(loss_full.is_finite());

    for cut in 1..=4 {
        let nc = spec.cut(cut).client_params;
        let wc = params[..nc].to_vec();
        let ws = params[nc..].to_vec();
        let smashed = rt.client_fwd(cut, &wc, &x).unwrap();
        let (loss_split, g_ws, g_s) = rt.server_grad(cut, &ws, &smashed, &y).unwrap();
        let g_wc = rt.client_grad(cut, &wc, &x, &g_s).unwrap();

        assert!(
            (loss_full - loss_split).abs() < 1e-6 * (1.0 + loss_full.abs()),
            "cut {cut}: loss {loss_split} != {loss_full}"
        );
        let mut g_split = g_wc.clone();
        g_split.extend(g_ws.iter().cloned());
        let diff = tensor::max_abs_diff(&g_split, &g_full);
        assert!(diff == 0.0, "cut {cut}: max grad diff {diff}");
    }
}

/// With a single client, SFL-GA, SFL and PSL are mathematically identical
/// (aggregation over one element is the identity) — all three must produce
/// the same model trajectory.
#[test]
fn single_client_schemes_coincide() {
    let manifest = manifest();
    let mut finals = Vec::new();
    for scheme in [SchemeKind::SflGa, SchemeKind::Sfl, SchemeKind::Psl] {
        let cfg = TrainConfig { seed: 5, ..test_cfg(scheme, 1, 2) };
        let mut t = Trainer::native(&manifest, cfg).unwrap();
        let stats = t.run(2).unwrap();
        let (loss, acc) = stats.last().unwrap().test.unwrap();
        finals.push((t.global_params(2), loss, acc));
    }
    for i in 1..finals.len() {
        let diff = tensor::max_abs_diff(&finals[0].0, &finals[i].0);
        assert!(diff < 1e-5, "scheme {i} diverged from scheme 0 by {diff}");
        assert!((finals[0].1 - finals[i].1).abs() < 1e-5);
    }
}

/// SFL-GA's shared-client-model invariant: zero drift across replicas.
#[test]
fn sfl_ga_clients_stay_identical() {
    let manifest = manifest();
    let mut cfg = test_cfg(SchemeKind::SflGa, 4, 2);
    cfg.eval_every = 10;
    let mut t = Trainer::native(&manifest, cfg).unwrap();
    t.run(2).unwrap();
    assert_eq!(t.client_drift(2), 0.0, "SFL-GA replicas must remain identical");
}

/// PSL clients drift (no aggregation), SFL clients re-sync every round.
#[test]
fn psl_drifts_sfl_resyncs() {
    let manifest = manifest();
    let drift = |scheme: SchemeKind| {
        let mut cfg = test_cfg(scheme, 4, 2);
        cfg.eval_every = 10;
        let mut t = Trainer::native(&manifest, cfg).unwrap();
        t.run(2).unwrap();
        t.client_drift(2)
    };
    assert!(drift(SchemeKind::Psl) > 0.0, "PSL must drift");
    assert_eq!(drift(SchemeKind::Sfl), 0.0, "SFL aggregates every round");
}

/// Short SFL-GA training improves over the initial model.
#[test]
fn sfl_ga_learns_in_ten_rounds() {
    let manifest = manifest();
    let cfg = TrainConfig { seed: 3, lr: 0.05, ..test_cfg(SchemeKind::SflGa, 4, 10) };
    let mut t = Trainer::native(&manifest, cfg).unwrap();
    let (loss0, acc0) = t.evaluate(1).unwrap();
    let stats = t.run(1).unwrap();
    let (loss1, acc1) = stats.last().unwrap().test.unwrap();
    assert!(loss1 < loss0, "loss {loss0} -> {loss1} did not improve");
    assert!(acc1 >= acc0, "acc {acc0} -> {acc1} regressed");
}

/// Communication accounting sanity at the run level: SFL-GA's cumulative
/// traffic is strictly below PSL's, which is below SFL's (same workload).
#[test]
fn cumulative_comm_ordering() {
    let manifest = manifest();
    let total = |scheme: SchemeKind| {
        let mut cfg = test_cfg(scheme, 4, 2);
        cfg.eval_every = 10;
        cfg.samples_per_client = 16;
        let mut t = Trainer::native(&manifest, cfg).unwrap();
        t.run(2)
            .unwrap()
            .iter()
            .map(|s| s.comm.total_bits())
            .sum::<f64>()
    };
    let ga = total(SchemeKind::SflGa);
    let psl = total(SchemeKind::Psl);
    let sfl = total(SchemeKind::Sfl);
    assert!(ga < psl && psl < sfl, "ordering violated: ga={ga} psl={psl} sfl={sfl}");
}

/// FL baseline trains through the same runtime.
#[test]
fn fl_baseline_learns() {
    let manifest = manifest();
    let cfg = TrainConfig { lr: 0.05, ..test_cfg(SchemeKind::Fl, 2, 6) };
    let mut t = Trainer::native(&manifest, cfg).unwrap();
    let (loss0, _) = t.evaluate(1).unwrap();
    let stats = t.run(1).unwrap();
    let (loss1, _) = stats.last().unwrap().test.unwrap();
    assert!(loss1 < loss0, "FL loss {loss0} -> {loss1}");
}

/// Dynamic cut switching (Algorithm 1 mode) keeps training stable.
#[test]
fn dynamic_cut_switching_is_stable() {
    let manifest = manifest();
    let cfg = test_cfg(SchemeKind::SflGa, 2, 6);
    let mut t = Trainer::native(&manifest, cfg).unwrap();
    let cuts = [1usize, 3, 2, 4, 2, 1];
    let mut last = None;
    for &v in &cuts {
        let st = t.draw_channel();
        let stats = t.run_round(v, &st).unwrap();
        assert!(stats.train_loss.is_finite());
        last = stats.test;
    }
    let (loss, acc) = last.unwrap();
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
}

/// Batcher + dataset wiring: every client sees only its own shard.
#[test]
fn batcher_respects_shards() {
    let manifest = manifest();
    let spec = manifest.for_dataset("mnist").unwrap().clone();
    let ds = generate(&spec, "mnist", 100, 4);
    let shards = sfl_ga::data::partition(&ds, 4, None, 2);
    for shard in &shards {
        let mut b = Batcher::new(shard.clone(), 8, 1);
        for _ in 0..10 {
            for i in b.next_batch() {
                assert!(shard.contains(&i));
            }
        }
    }
}

/// The PJRT mirror: same invariant through the XLA-compiled artifacts.
#[cfg(feature = "pjrt")]
mod pjrt {
    use std::path::{Path, PathBuf};
    use super::*;

    fn artifacts() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn split_gradients_equal_full_through_pjrt() {
        let Some(dir) = artifacts() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let rt = ModelRuntime::load(&dir, &manifest, "mnist").unwrap();
        let spec = rt.spec().clone();
        let params = init_params(&spec, 42);
        let ds = generate(&spec, "mnist", 64, 9);
        let idx: Vec<usize> = (0..spec.train_batch).collect();
        let (x, y) = ds.batch(&idx);

        let (loss_full, g_full) = rt.full_grad(&params, &x, &y).unwrap();
        for cut in 1..=4 {
            let nc = spec.cut(cut).client_params;
            let wc = params[..nc].to_vec();
            let ws = params[nc..].to_vec();
            let smashed = rt.client_fwd(cut, &wc, &x).unwrap();
            let (loss_split, g_ws, g_s) = rt.server_grad(cut, &ws, &smashed, &y).unwrap();
            let g_wc = rt.client_grad(cut, &wc, &x, &g_s).unwrap();
            assert!(
                (loss_full - loss_split).abs() < 1e-4 * (1.0 + loss_full.abs()),
                "cut {cut}: loss {loss_split} != {loss_full}"
            );
            let mut g_split = g_wc.clone();
            g_split.extend(g_ws.iter().cloned());
            let diff = tensor::max_abs_diff(&g_split, &g_full);
            assert!(diff < 2e-3, "cut {cut}: max grad diff {diff}");
        }
    }

    /// Native and PJRT must agree on the same inputs (backend parity).
    #[test]
    fn native_and_pjrt_agree_on_full_grad() {
        let Some(dir) = artifacts() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let pjrt = ModelRuntime::load(&dir, &manifest, "mnist").unwrap();
        let native = ModelRuntime::native(&manifest, "mnist").unwrap();
        let spec = pjrt.spec().clone();
        let params = init_params(&spec, 11);
        let ds = generate(&spec, "mnist", 64, 13);
        let idx: Vec<usize> = (0..spec.train_batch).collect();
        let (x, y) = ds.batch(&idx);
        let (lp, gp) = pjrt.full_grad(&params, &x, &y).unwrap();
        let (ln, gn) = native.full_grad(&params, &x, &y).unwrap();
        assert!((lp - ln).abs() < 1e-4 * (1.0 + lp.abs()));
        assert!(tensor::max_abs_diff(&gp, &gn) < 2e-3);
    }
}
