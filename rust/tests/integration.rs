//! Integration tests across the whole stack: PJRT runtime + manifest +
//! data + coordinator.  These run against the real AOT artifacts and are
//! skipped (not failed) when `make artifacts` hasn't been run.

use std::path::{Path, PathBuf};

use sfl_ga::coordinator::{AllocPolicy, SchemeKind, TrainConfig, Trainer};
use sfl_ga::data::init::init_params;
use sfl_ga::data::{generate, Batcher};
use sfl_ga::model::Manifest;
use sfl_ga::runtime::ModelRuntime;
use sfl_ga::tensor;

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

/// rust-side mirror of python's split-equivalence test, through PJRT:
/// client_fwd ∘ server_grad ∘ client_grad must equal full_grad.
#[test]
fn split_gradients_equal_full_through_pjrt() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = ModelRuntime::load(&dir, &manifest, "mnist").unwrap();
    let spec = rt.spec().clone();
    let params = init_params(&spec, 42);
    let ds = generate(&spec, "mnist", 64, 9);
    let idx: Vec<usize> = (0..spec.train_batch).collect();
    let (x, y) = ds.batch(&idx);

    let (loss_full, g_full) = rt.full_grad(&params, &x, &y).unwrap();

    for cut in 1..=4 {
        let nc = spec.cut(cut).client_params;
        let wc = params[..nc].to_vec();
        let ws = params[nc..].to_vec();
        let smashed = rt.client_fwd(cut, &wc, &x).unwrap();
        let (loss_split, g_ws, g_s) = rt.server_grad(cut, &ws, &smashed, &y).unwrap();
        let g_wc = rt.client_grad(cut, &wc, &x, &g_s).unwrap();

        assert!(
            (loss_full - loss_split).abs() < 1e-4 * (1.0 + loss_full.abs()),
            "cut {cut}: loss {loss_split} != {loss_full}"
        );
        let mut g_split = g_wc.clone();
        g_split.extend(g_ws.iter().cloned());
        let diff = tensor::max_abs_diff(&g_split, &g_full);
        assert!(diff < 2e-3, "cut {cut}: max grad diff {diff}");
    }
}

/// With a single client, SFL-GA, SFL and PSL are mathematically identical
/// (aggregation over one element is the identity) — all three must produce
/// the same model trajectory.
#[test]
fn single_client_schemes_coincide() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let mut finals = Vec::new();
    for scheme in [SchemeKind::SflGa, SchemeKind::Sfl, SchemeKind::Psl] {
        let cfg = TrainConfig {
            scheme,
            num_clients: 1,
            rounds: 3,
            eval_every: 3,
            samples_per_client: 64,
            seed: 5,
            alloc: AllocPolicy::Equal,
            ..Default::default()
        };
        let mut t = Trainer::new(&dir, &manifest, cfg).unwrap();
        let stats = t.run(2).unwrap();
        let (loss, acc) = stats.last().unwrap().test.unwrap();
        finals.push((t.global_params(2), loss, acc));
    }
    for i in 1..finals.len() {
        let diff = tensor::max_abs_diff(&finals[0].0, &finals[i].0);
        assert!(diff < 1e-5, "scheme {i} diverged from scheme 0 by {diff}");
        assert!((finals[0].1 - finals[i].1).abs() < 1e-5);
    }
}

/// Deterministic: same seed ⇒ identical metrics; different seed ⇒ not.
#[test]
fn training_is_seed_deterministic() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let run = |seed: u64| {
        let cfg = TrainConfig {
            rounds: 2,
            eval_every: 2,
            samples_per_client: 64,
            seed,
            alloc: AllocPolicy::Equal,
            ..Default::default()
        };
        let mut t = Trainer::new(&dir, &manifest, cfg).unwrap();
        let stats = t.run(1).unwrap();
        (stats.last().unwrap().train_loss, stats.last().unwrap().test.unwrap())
    };
    let a = run(7);
    let b = run(7);
    let c = run(8);
    assert_eq!(a, b);
    assert_ne!(a, c);
}

/// SFL-GA's shared-client-model invariant: zero drift across replicas.
#[test]
fn sfl_ga_clients_stay_identical() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let cfg = TrainConfig {
        scheme: SchemeKind::SflGa,
        num_clients: 4,
        rounds: 3,
        eval_every: 10,
        samples_per_client: 64,
        alloc: AllocPolicy::Equal,
        ..Default::default()
    };
    let mut t = Trainer::new(&dir, &manifest, cfg).unwrap();
    t.run(2).unwrap();
    assert_eq!(t.client_drift(2), 0.0, "SFL-GA replicas must remain identical");
}

/// PSL clients drift (no aggregation), SFL clients re-sync every round.
#[test]
fn psl_drifts_sfl_resyncs() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let drift = |scheme: SchemeKind| {
        let cfg = TrainConfig {
            scheme,
            num_clients: 4,
            rounds: 3,
            eval_every: 10,
            samples_per_client: 64,
            alloc: AllocPolicy::Equal,
            ..Default::default()
        };
        let mut t = Trainer::new(&dir, &manifest, cfg).unwrap();
        t.run(2).unwrap();
        t.client_drift(2)
    };
    assert!(drift(SchemeKind::Psl) > 0.0, "PSL must drift");
    assert_eq!(drift(SchemeKind::Sfl), 0.0, "SFL aggregates every round");
}

/// Short SFL-GA training improves over the initial model.
#[test]
fn sfl_ga_learns_in_ten_rounds() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let cfg = TrainConfig {
        rounds: 10,
        eval_every: 10,
        samples_per_client: 128,
        alloc: AllocPolicy::Equal,
        seed: 3,
        ..Default::default()
    };
    let mut t = Trainer::new(&dir, &manifest, cfg).unwrap();
    let (loss0, acc0) = t.evaluate(1).unwrap();
    let stats = t.run(1).unwrap();
    let (loss1, acc1) = stats.last().unwrap().test.unwrap();
    assert!(loss1 < loss0, "loss {loss0} -> {loss1} did not improve");
    assert!(acc1 >= acc0, "acc {acc0} -> {acc1} regressed");
}

/// Communication accounting sanity at the run level: SFL-GA's cumulative
/// traffic is strictly below PSL's, which is below SFL's (same workload).
#[test]
fn cumulative_comm_ordering() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let total = |scheme: SchemeKind| {
        let cfg = TrainConfig {
            scheme,
            rounds: 2,
            eval_every: 10,
            samples_per_client: 64,
            alloc: AllocPolicy::Equal,
            ..Default::default()
        };
        let mut t = Trainer::new(&dir, &manifest, cfg).unwrap();
        t.run(2)
            .unwrap()
            .iter()
            .map(|s| s.comm.total_bits())
            .sum::<f64>()
    };
    let ga = total(SchemeKind::SflGa);
    let psl = total(SchemeKind::Psl);
    let sfl = total(SchemeKind::Sfl);
    assert!(ga < psl && psl < sfl, "ordering violated: ga={ga} psl={psl} sfl={sfl}");
}

/// FL baseline trains through the same runtime.
#[test]
fn fl_baseline_learns() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let cfg = TrainConfig {
        scheme: SchemeKind::Fl,
        rounds: 8,
        eval_every: 8,
        samples_per_client: 128,
        alloc: AllocPolicy::Equal,
        ..Default::default()
    };
    let mut t = Trainer::new(&dir, &manifest, cfg).unwrap();
    let (loss0, _) = t.evaluate(1).unwrap();
    let stats = t.run(1).unwrap();
    let (loss1, _) = stats.last().unwrap().test.unwrap();
    assert!(loss1 < loss0, "FL loss {loss0} -> {loss1}");
}

/// Dynamic cut switching (Algorithm 1 mode) keeps training stable.
#[test]
fn dynamic_cut_switching_is_stable() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let cfg = TrainConfig {
        rounds: 6,
        eval_every: 6,
        samples_per_client: 64,
        alloc: AllocPolicy::Equal,
        ..Default::default()
    };
    let mut t = Trainer::new(&dir, &manifest, cfg).unwrap();
    let cuts = [1usize, 3, 2, 4, 2, 1];
    let mut last = None;
    for &v in &cuts {
        let st = t.draw_channel();
        let stats = t.run_round(v, &st).unwrap();
        assert!(stats.train_loss.is_finite());
        last = stats.test;
    }
    let (loss, acc) = last.unwrap();
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
}

/// Batcher + dataset wiring: every client sees only its own shard.
#[test]
fn batcher_respects_shards() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest.for_dataset("mnist").unwrap().clone();
    let ds = generate(&spec, "mnist", 100, 4);
    let shards = sfl_ga::data::partition(&ds, 4, None, 2);
    for shard in &shards {
        let mut b = Batcher::new(shard.clone(), 8, 1);
        for _ in 0..10 {
            for i in b.next_batch() {
                assert!(shard.contains(&i));
            }
        }
    }
}
