//! Transport equivalence suite (DESIGN.md §Transport): the networked
//! round engine is a *transport*, not a different algorithm.
//!
//! * loopback [`NetTrainer`] ≡ the in-process [`Trainer`] — bitwise, per
//!   scheme × cut (stats digests AND final global parameters);
//! * real TCP participants (spawned `sfl-participant` binaries) ≡
//!   loopback — bitwise, same digests.
//!
//! Together with the executor's threads=N ≡ 1 guarantee this pins the
//! whole chain: simulator ≡ loopback ≡ multi-process TCP.

mod chaos_harness;

use std::net::TcpListener;
use std::time::Duration;

use chaos_harness::{spawn_participant, Watchdog};
use sfl_ga::coordinator::{
    params_digest, stats_digest, NetTrainer, SchemeKind, TrainConfig, Trainer,
};
use sfl_ga::model::Manifest;
use sfl_ga::runtime::TcpTransport;

/// Small but non-degenerate run: 2 rounds, eval every round, tiny shards.
fn cfg(scheme: SchemeKind, n: usize) -> TrainConfig {
    TrainConfig {
        scheme,
        num_clients: n,
        rounds: 2,
        tau: 1,
        samples_per_client: 32,
        test_samples: 64,
        seed: 17,
        eval_every: 1,
        threads: 1,
        ..Default::default()
    }
}

/// Digest-pair fingerprint of one networked run over an already-joined
/// transport.
fn run_net<T: sfl_ga::runtime::Transport>(
    manifest: &Manifest,
    cfg: TrainConfig,
    deadline: Duration,
    transport: T,
    cut: usize,
) -> (u64, u64) {
    let mut nt = NetTrainer::new(manifest, cfg, deadline, transport).expect("net trainer");
    let stats = nt.run(cut).expect("net run");
    assert!(nt.dropped().is_empty(), "no faults injected, yet {:?} dropped", nt.dropped());
    let digests = (stats_digest(&stats), params_digest(&nt.global_params(cut)));
    nt.shutdown();
    digests
}

#[test]
fn loopback_matches_in_process_trainer() {
    let manifest = Manifest::builtin();
    let n = 3;
    for scheme in [SchemeKind::SflGa, SchemeKind::Sfl] {
        for cut in [1usize, 2] {
            let mut trainer = Trainer::native(&manifest, cfg(scheme, n)).expect("trainer");
            let sim_stats = trainer.run(cut).expect("sim run");
            let sim = (stats_digest(&sim_stats), params_digest(&trainer.global_params(cut)));

            let nt = NetTrainer::loopback(&manifest, cfg(scheme, n), n).expect("loopback");
            let net = {
                let mut nt = nt;
                let stats = nt.run(cut).expect("loopback run");
                (stats_digest(&stats), params_digest(&nt.global_params(cut)))
            };
            assert_eq!(
                sim, net,
                "loopback diverged from the in-process trainer ({} at cut {cut})",
                scheme.name()
            );
        }
    }
}

#[test]
fn tcp_matches_loopback() {
    let _wd = Watchdog::arm("tcp_matches_loopback", Duration::from_secs(240));
    let manifest = Manifest::builtin();
    let n = 2;
    for scheme in [SchemeKind::SflGa, SchemeKind::Sfl] {
        for cut in [1usize, 2] {
            let loopback = {
                let mut nt = NetTrainer::loopback(&manifest, cfg(scheme, n), n).expect("loopback");
                let stats = nt.run(cut).expect("loopback run");
                (stats_digest(&stats), params_digest(&nt.global_params(cut)))
            };

            let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
            let addr = listener.local_addr().expect("addr").to_string();
            let _participants: Vec<_> =
                (0..n as u64).map(|id| spawn_participant(&addr, id)).collect();
            let transport = TcpTransport::accept(listener, n, Duration::from_secs(30))
                .expect("rendezvous");
            assert_eq!(transport.joined(), (0..n as u64).collect::<Vec<_>>());
            let tcp = run_net(&manifest, cfg(scheme, n), Duration::from_secs(60), transport, cut);

            assert_eq!(
                loopback, tcp,
                "TCP federation diverged from loopback ({} at cut {cut})",
                scheme.name()
            );
        }
    }
}
