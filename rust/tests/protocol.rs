//! Protocol fuzz/property suite (DESIGN.md §Transport): the two wire
//! contracts the networked runtime rests on.
//!
//! 1. **Bit-exact roundtrip** — `decode(encode(m))` reproduces `m` for
//!    every message type, including non-finite float payloads (compared
//!    at the byte level, since NaN breaks structural equality on
//!    purpose).
//! 2. **The decoder never panics** — arbitrary bytes, truncated
//!    prefixes and random single-byte corruptions of valid encodings all
//!    produce `Ok`/`Err`, never a panic or runaway allocation.

use sfl_ga::prop_assert;
use sfl_ga::protocol::wire::{read_frame, write_frame};
use sfl_ga::protocol::{Msg, RunSetup, PROTO_VERSION};
use sfl_ga::runtime::Tensor;
use sfl_ga::tensor::Params;
use sfl_ga::util::proptest::check;
use sfl_ga::util::rng::Pcg;

// ----------------------------------------------------------- generators

/// Random f32: finite-and-tame, or any bit pattern at all (NaNs, infs,
/// subnormals) depending on `finite`.
fn gen_f32(rng: &mut Pcg, finite: bool) -> f32 {
    if finite {
        rng.range(-8.0, 8.0) as f32
    } else {
        f32::from_bits(rng.next_u32())
    }
}

fn gen_params(rng: &mut Pcg, finite: bool) -> Params {
    (0..rng.below(4))
        .map(|_| (0..rng.below(16)).map(|_| gen_f32(rng, finite)).collect())
        .collect()
}

fn gen_tensor(rng: &mut Pcg, finite: bool) -> Tensor {
    let shape = vec![1 + rng.below(3), 1 + rng.below(5)];
    let n: usize = shape.iter().product();
    Tensor::new((0..n).map(|_| gen_f32(rng, finite)).collect(), shape)
}

fn gen_string(rng: &mut Pcg) -> String {
    const ALPHABET: &[u8] = b"abcxyz0189:._-/ \xCF\x80"; // includes UTF-8 "π"
    let mut s = String::new();
    for _ in 0..rng.below(12) {
        match rng.below(ALPHABET.len() - 1) {
            i if i < ALPHABET.len() - 2 => s.push(ALPHABET[i] as char),
            _ => s.push('π'),
        }
    }
    s
}

/// One random message covering every variant (and with it every wire
/// primitive: strings, scalars, params, tensors).
fn gen_msg(rng: &mut Pcg, finite: bool) -> Msg {
    match rng.below(12) {
        0 => Msg::Join { client: rng.next_u64(), version: PROTO_VERSION },
        1 => Msg::Welcome {
            setup: RunSetup {
                dataset: gen_string(rng),
                seed: rng.next_u64(),
                partition: gen_string(rng),
                samples_per_client: rng.below(4096),
                model: gen_string(rng),
                num_cuts: rng.below(64) as u32,
            },
        },
        2 => Msg::FwdReq {
            seq: rng.next_u64(),
            // Any 1-based id is wire-legal; menu membership is the
            // receiving node's check, not the codec's.
            cut: 1 + rng.below(16) as u32,
            step: rng.next_u64(),
            wc: gen_params(rng, finite),
        },
        3 => Msg::FwdOk {
            seq: rng.next_u64(),
            smashed: gen_tensor(rng, finite),
            labels: gen_tensor(rng, finite),
        },
        4 => Msg::BwdReq { seq: rng.next_u64(), cotangent: gen_tensor(rng, finite) },
        5 => Msg::BwdOk { seq: rng.next_u64(), grad: gen_params(rng, finite) },
        6 => Msg::FullReq {
            seq: rng.next_u64(),
            step0: rng.next_u64(),
            tau: 1 + rng.below(16) as u32,
            lr: gen_f32(rng, finite),
            w: gen_params(rng, finite),
        },
        7 => Msg::FullOk {
            seq: rng.next_u64(),
            loss: if finite { rng.range(-1e3, 1e3) } else { f64::from_bits(rng.next_u64()) },
            w: gen_params(rng, finite),
        },
        8 => Msg::RoundDone { round: rng.next_u64() },
        9 => Msg::Rejoin { client: rng.next_u64(), version: PROTO_VERSION },
        10 => Msg::Sync {
            round: rng.next_u64(),
            setup: RunSetup {
                dataset: gen_string(rng),
                seed: rng.next_u64(),
                partition: gen_string(rng),
                samples_per_client: rng.below(4096),
                model: gen_string(rng),
                num_cuts: rng.below(64) as u32,
            },
        },
        _ => Msg::Shutdown,
    }
}

// ------------------------------------------------------------ roundtrip

#[test]
fn roundtrip_is_structural_for_finite_payloads() {
    check("roundtrip-structural", 512, |rng| {
        let msg = gen_msg(rng, true);
        let bytes = msg.encode();
        let back = Msg::decode(&bytes)
            .map_err(|e| format!("well-formed {} failed to decode: {e:#}", msg.name()))?;
        prop_assert!(back == msg, "{} changed across the wire", msg.name());
        Ok(())
    });
}

#[test]
fn roundtrip_is_bit_exact_for_arbitrary_float_bits() {
    // NaN != NaN makes structural equality the wrong oracle here; the
    // stronger claim is that re-encoding the decoded message reproduces
    // the original bytes exactly (floats travel as raw bit patterns).
    check("roundtrip-bit-exact", 512, |rng| {
        let msg = gen_msg(rng, false);
        let bytes = msg.encode();
        let back = Msg::decode(&bytes)
            .map_err(|e| format!("well-formed {} failed to decode: {e:#}", msg.name()))?;
        prop_assert!(
            back.encode() == bytes,
            "{} did not re-encode to the same {} bytes",
            msg.name(),
            bytes.len()
        );
        Ok(())
    });
}

// ------------------------------------------------- decoder never panics

#[test]
fn every_strict_prefix_is_rejected_without_panic() {
    // The read sequence is deterministic, so a strict prefix of a valid
    // encoding must hit a truncation error — it can never silently
    // decode to something shorter.
    check("prefix-rejection", 256, |rng| {
        let bytes = gen_msg(rng, false).encode();
        for cut in 0..bytes.len() {
            prop_assert!(
                Msg::decode(&bytes[..cut]).is_err(),
                "prefix of {cut}/{} bytes decoded",
                bytes.len()
            );
        }
        Ok(())
    });
}

#[test]
fn corrupted_encodings_never_panic() {
    check("corruption-tolerance", 512, |rng| {
        let mut bytes = gen_msg(rng, false).encode();
        for _ in 0..4 {
            if bytes.is_empty() {
                break;
            }
            let at = rng.below(bytes.len());
            bytes[at] ^= (1 + rng.below(255)) as u8;
        }
        // Ok or Err are both acceptable outcomes; panicking or OOM on a
        // flipped length prefix is the bug class under test.
        let _ = Msg::decode(&bytes);
        Ok(())
    });
}

#[test]
fn arbitrary_byte_soup_never_panics() {
    check("byte-soup", 1024, |rng| {
        let bytes: Vec<u8> = (0..rng.below(192)).map(|_| rng.next_u32() as u8).collect();
        let _ = Msg::decode(&bytes);
        Ok(())
    });
}

// -------------------------------------------------------------- framing

#[test]
fn framed_messages_roundtrip_through_a_stream() {
    check("frame-roundtrip", 64, |rng| {
        let msgs: Vec<Msg> = (0..1 + rng.below(5)).map(|_| gen_msg(rng, false)).collect();
        let mut stream = Vec::new();
        for m in &msgs {
            write_frame(&mut stream, &m.encode()).map_err(|e| format!("write: {e:#}"))?;
        }
        let mut cur = std::io::Cursor::new(stream);
        for m in &msgs {
            let payload = read_frame(&mut cur)
                .map_err(|e| format!("read: {e:#}"))?
                .ok_or("premature EOF")?;
            prop_assert!(payload == m.encode(), "frame payload drifted for {}", m.name());
        }
        prop_assert!(
            read_frame(&mut cur).map_err(|e| format!("eof read: {e:#}"))?.is_none(),
            "expected clean EOF after {} frames",
            msgs.len()
        );
        Ok(())
    });
}

#[test]
fn truncated_frame_streams_error_not_panic() {
    check("frame-truncation", 128, |rng| {
        let mut stream = Vec::new();
        write_frame(&mut stream, &gen_msg(rng, false).encode()).map_err(|e| format!("{e:#}"))?;
        let cut = rng.below(stream.len());
        if cut == 0 {
            return Ok(()); // empty stream is a clean EOF, nothing to assert
        }
        stream.truncate(cut);
        let result = read_frame(&mut std::io::Cursor::new(stream));
        prop_assert!(
            match &result {
                Ok(Some(_)) => false,
                // read_exact reports UnexpectedEof even after partial
                // bytes, so a cut inside the 4-byte length prefix is
                // indistinguishable from a clean boundary EOF.
                Ok(None) => cut < 4,
                Err(_) => true,
            },
            "truncated frame at {cut} gave {result:?}"
        );
        Ok(())
    });
}
