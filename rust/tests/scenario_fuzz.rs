//! Scenario fuzz: degenerate cohorts must degrade gracefully, never
//! panic, and keep the reproducibility contract.
//!
//! Random combinations of the scenario axes pushed to their edges —
//! participation → 0 (the cohort clamps to one client), every client a
//! straggler, extreme non-IID partitions — across random schemes and
//! cuts.  Each case must (a) complete, (b) report a finite loss over a
//! non-empty renormalized cohort, and (c) satisfy reset(s) ≡ fresh(s)
//! bitwise.  (The networked engine's own degenerate cohorts — an empty
//! federation, every participant dropped — are unit-tested in
//! `coordinator::net`.)
//!
//! The churn wall fuzzes the networked engine the same way: random
//! [`ChurnTrace`]s — departures, same-round cold rejoins,
//! join-then-immediately-die, everyone-leaves — driven through the
//! loopback [`NetTrainer`].  Every trace must either complete with
//! finite stats or fail with the clean below-quorum error, and
//! reset(s) ≡ fresh(s) must survive churn.

use sfl_ga::coordinator::{
    params_digest, stats_digest, NetTrainer, SchemeKind, TrainConfig, Trainer,
};
use sfl_ga::data::partition::Partition;
use sfl_ga::model::Manifest;
use sfl_ga::prop_assert;
use sfl_ga::scenario::{ChurnEvent, ChurnTrace, ScenarioConfig, StragglerConfig};
use sfl_ga::util::proptest::check;
use sfl_ga::util::rng::Pcg;

/// A random scenario biased toward the degenerate edges.
fn gen_scenario(rng: &mut Pcg) -> ScenarioConfig {
    let partition = match rng.below(3) {
        0 => Partition::Iid,
        1 => Partition::Dirichlet(0.05 + rng.uniform()), // near-degenerate non-IID
        _ => Partition::Shards(1 + rng.below(3)),
    };
    let participation = match rng.below(3) {
        0 => 1e-12, // cohort clamps to a single client
        1 => rng.range(0.05, 0.95),
        _ => 1.0,
    };
    let straggler = match rng.below(3) {
        0 => StragglerConfig::default(),
        1 => StragglerConfig { frac: 1.0, factor: 16.0 }, // ALL stragglers
        _ => StragglerConfig { frac: rng.uniform(), factor: 1.0 + rng.uniform() * 8.0 },
    };
    ScenarioConfig { partition, participation, straggler }
}

fn tiny_cfg(rng: &mut Pcg) -> (TrainConfig, usize) {
    let schemes = SchemeKind::all();
    let cfg = TrainConfig {
        scheme: schemes[rng.below(schemes.len())],
        num_clients: 2 + rng.below(3),
        rounds: 1,
        tau: 1,
        samples_per_client: 32,
        test_samples: 64,
        scenario: gen_scenario(rng),
        seed: 0xFA11 ^ rng.next_u64(),
        eval_every: 1,
        threads: 1,
        ..Default::default()
    };
    let cut = 1 + rng.below(2);
    (cfg, cut)
}

#[test]
fn degenerate_scenarios_complete_with_finite_renormalized_rounds() {
    let manifest = Manifest::builtin();
    check("degenerate-scenarios", 8, |rng| {
        let (cfg, cut) = tiny_cfg(rng);
        let n = cfg.num_clients;
        let label = format!(
            "{} n={n} cut={cut} [{}]",
            cfg.scheme.name(),
            cfg.scenario.describe()
        );
        let mut trainer =
            Trainer::native(&manifest, cfg).map_err(|e| format!("{label}: construct: {e:#}"))?;
        let stats = trainer.run(cut).map_err(|e| format!("{label}: run: {e:#}"))?;
        prop_assert!(stats.len() == 1, "{label}: expected 1 round, got {}", stats.len());
        let s = &stats[0];
        prop_assert!(s.train_loss.is_finite(), "{label}: non-finite loss {}", s.train_loss);
        prop_assert!(
            (1..=n).contains(&s.participants),
            "{label}: cohort of {} outside 1..={n}",
            s.participants
        );
        let (tl, ta) =
            s.test.ok_or_else(|| format!("{label}: eval round missing test stats"))?;
        prop_assert!(tl.is_finite(), "{label}: non-finite test loss {tl}");
        prop_assert!((0.0..=1.0).contains(&ta), "{label}: accuracy {ta} outside [0, 1]");
        Ok(())
    });
}

#[test]
fn reset_equals_fresh_under_degenerate_scenarios() {
    let manifest = Manifest::builtin();
    check("reset-equals-fresh", 4, |rng| {
        let (cfg, cut) = tiny_cfg(rng);
        let label = format!("{} [{}]", cfg.scheme.name(), cfg.scenario.describe());
        let orig_seed = cfg.seed;
        let reseed = cfg.seed ^ 0xBEEF;

        let mut trainer = Trainer::native(&manifest, cfg.clone())
            .map_err(|e| format!("{label}: construct: {e:#}"))?;
        let first = trainer.run(cut).map_err(|e| format!("{label}: run 1: {e:#}"))?;
        let first = (stats_digest(&first), params_digest(&trainer.global_params(cut)));

        // Reset to a different seed, run, and demand bitwise agreement
        // with a from-scratch trainer at that seed — then reset back and
        // demand the original digests again.
        trainer.reset(reseed);
        let reset_run = trainer.run(cut).map_err(|e| format!("{label}: reset run: {e:#}"))?;
        let reset_run =
            (stats_digest(&reset_run), params_digest(&trainer.global_params(cut)));
        let mut fresh = Trainer::native(&manifest, TrainConfig { seed: reseed, ..cfg })
            .map_err(|e| format!("{label}: fresh construct: {e:#}"))?;
        let fresh_run = fresh.run(cut).map_err(|e| format!("{label}: fresh run: {e:#}"))?;
        let fresh_run = (stats_digest(&fresh_run), params_digest(&fresh.global_params(cut)));
        prop_assert!(reset_run == fresh_run, "{label}: reset({reseed:#x}) != fresh");

        trainer.reset(orig_seed);
        let back = trainer.run(cut).map_err(|e| format!("{label}: reset-back run: {e:#}"))?;
        let back = (stats_digest(&back), params_digest(&trainer.global_params(cut)));
        prop_assert!(back == first, "{label}: reset back to {orig_seed:#x} lost the original run");
        Ok(())
    });
}

// ------------------------------------------------------------ churn wall

/// Networked-run config: full participation, no simulated stragglers
/// (the networked engine rejects both — real churn is the chaos here).
fn churn_cfg(rng: &mut Pcg, n: usize, rounds: usize) -> TrainConfig {
    let schemes = SchemeKind::all();
    TrainConfig {
        scheme: schemes[rng.below(schemes.len())],
        num_clients: n,
        rounds,
        tau: 1,
        samples_per_client: 32,
        test_samples: 64,
        seed: 0xC4A0 ^ rng.next_u64(),
        eval_every: 1,
        threads: 1,
        ..Default::default()
    }
}

/// A random churn trace biased toward the nasty edges.  Round 0 is left
/// calm so every run starts with the whole federation.
fn gen_trace(rng: &mut Pcg, n: u64, rounds: u64) -> ChurnTrace {
    let mut trace = ChurnTrace::new();
    for r in 1..rounds {
        match rng.below(5) {
            0 => {} // calm round
            1 => trace.push(r, ChurnEvent::Leave(rng.below(n as usize) as u64)),
            2 => {
                // Same-round cold rejoin: leave then immediately re-admit.
                let id = rng.below(n as usize) as u64;
                trace.push(r, ChurnEvent::Leave(id));
                trace.push(r, ChurnEvent::Join(id));
            }
            3 => {
                // Join-then-immediately-die — possibly a brand-new id
                // beyond the initial population span.
                let id = n + rng.below(2) as u64;
                trace.push(r, ChurnEvent::Join(id));
                trace.push(r, ChurnEvent::Leave(id));
            }
            _ => {
                // Everyone leaves: the run must end in the clean
                // below-quorum error, never a panic.
                for id in 0..n {
                    trace.push(r, ChurnEvent::Leave(id));
                }
            }
        }
    }
    trace
}

/// Like [`gen_trace`] but guaranteed to keep client 0 live, so the run
/// always completes (for the reset-equality property).
fn gen_safe_trace(rng: &mut Pcg, n: u64, rounds: u64) -> ChurnTrace {
    let mut trace = ChurnTrace::new();
    for r in 1..rounds {
        match rng.below(4) {
            0 => {}
            1 => trace.push(r, ChurnEvent::Leave(1 + rng.below((n - 1) as usize) as u64)),
            2 => {
                let id = 1 + rng.below((n - 1) as usize) as u64;
                trace.push(r, ChurnEvent::Leave(id));
                trace.push(r, ChurnEvent::Join(id));
            }
            _ => {
                let id = n + rng.below(2) as u64;
                trace.push(r, ChurnEvent::Join(id));
                trace.push(r, ChurnEvent::Leave(id));
            }
        }
    }
    trace
}

#[test]
fn churn_traces_never_panic_and_keep_stats_finite() {
    let manifest = Manifest::builtin();
    check("churn-traces", 6, |rng| {
        let n = 2 + rng.below(2);
        let rounds = 2 + rng.below(2);
        let cfg = churn_cfg(rng, n, rounds);
        let cut = 1 + rng.below(2);
        let trace = gen_trace(rng, n as u64, rounds as u64);
        let label = format!("{} n={n} rounds={rounds} cut={cut} {trace:?}", cfg.scheme.name());
        let mut nt = NetTrainer::loopback(&manifest, cfg, n)
            .map_err(|e| format!("{label}: construct: {e:#}"))?;
        match nt.run_churn(cut, &trace) {
            Ok(stats) => {
                prop_assert!(stats.len() == rounds, "{label}: {} of {rounds} rounds", stats.len());
                for s in &stats {
                    prop_assert!(
                        s.train_loss.is_finite(),
                        "{label}: non-finite loss {} at round {}",
                        s.train_loss,
                        s.round
                    );
                    prop_assert!(s.participants >= 1, "{label}: empty cohort at round {}", s.round);
                    let (tl, ta) = s
                        .test
                        .ok_or_else(|| format!("{label}: round {} missing test stats", s.round))?;
                    prop_assert!(tl.is_finite(), "{label}: non-finite test loss {tl}");
                    prop_assert!((0.0..=1.0).contains(&ta), "{label}: accuracy {ta}");
                }
            }
            Err(e) => {
                // The only legal failure: the cohort emptied and the
                // (zero-wait) quorum pause expired — a clean error that
                // names the drop history, not a panic or a junk state.
                let msg = format!("{e:#}");
                prop_assert!(
                    msg.contains("below quorum") && msg.contains("dropped in order"),
                    "{label}: unexpected error: {msg}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn reset_equals_fresh_under_churn() {
    let manifest = Manifest::builtin();
    check("churn-reset", 3, |rng| {
        let n = 2 + rng.below(2);
        let rounds = 2 + rng.below(2);
        let cfg = churn_cfg(rng, n, rounds);
        let cut = 1 + rng.below(2);
        let trace = gen_safe_trace(rng, n as u64, rounds as u64);
        let label = format!("{} n={n} rounds={rounds} cut={cut} {trace:?}", cfg.scheme.name());
        let reseed = cfg.seed ^ 0xBEEF;

        let mut nt = NetTrainer::loopback(&manifest, cfg.clone(), n)
            .map_err(|e| format!("{label}: construct: {e:#}"))?;
        nt.run_churn(cut, &trace).map_err(|e| format!("{label}: run 1: {e:#}"))?;

        // Reset to a new seed and replay the SAME churn trace: the result
        // must be bitwise the fresh federation at that seed under that
        // trace — churn must not leak state across reset.
        nt.reset(reseed).map_err(|e| format!("{label}: reset: {e:#}"))?;
        let replay = nt.run_churn(cut, &trace).map_err(|e| format!("{label}: run 2: {e:#}"))?;
        let replay = (stats_digest(&replay), params_digest(&nt.global_params(cut)));

        let mut fresh =
            NetTrainer::loopback(&manifest, TrainConfig { seed: reseed, ..cfg }, n)
                .map_err(|e| format!("{label}: fresh construct: {e:#}"))?;
        let fresh_run =
            fresh.run_churn(cut, &trace).map_err(|e| format!("{label}: fresh run: {e:#}"))?;
        let fresh_run = (stats_digest(&fresh_run), params_digest(&fresh.global_params(cut)));
        prop_assert!(replay == fresh_run, "{label}: reset({reseed:#x}) != fresh under churn");
        Ok(())
    });
}
