//! Scenario fuzz: degenerate cohorts must degrade gracefully, never
//! panic, and keep the reproducibility contract.
//!
//! Random combinations of the scenario axes pushed to their edges —
//! participation → 0 (the cohort clamps to one client), every client a
//! straggler, extreme non-IID partitions — across random schemes and
//! cuts.  Each case must (a) complete, (b) report a finite loss over a
//! non-empty renormalized cohort, and (c) satisfy reset(s) ≡ fresh(s)
//! bitwise.  (The networked engine's own degenerate cohorts — an empty
//! federation, every participant dropped — are unit-tested in
//! `coordinator::net`.)

use sfl_ga::coordinator::{params_digest, stats_digest, SchemeKind, TrainConfig, Trainer};
use sfl_ga::data::partition::Partition;
use sfl_ga::model::Manifest;
use sfl_ga::prop_assert;
use sfl_ga::scenario::{ScenarioConfig, StragglerConfig};
use sfl_ga::util::proptest::check;
use sfl_ga::util::rng::Pcg;

/// A random scenario biased toward the degenerate edges.
fn gen_scenario(rng: &mut Pcg) -> ScenarioConfig {
    let partition = match rng.below(3) {
        0 => Partition::Iid,
        1 => Partition::Dirichlet(0.05 + rng.uniform()), // near-degenerate non-IID
        _ => Partition::Shards(1 + rng.below(3)),
    };
    let participation = match rng.below(3) {
        0 => 1e-12, // cohort clamps to a single client
        1 => rng.range(0.05, 0.95),
        _ => 1.0,
    };
    let straggler = match rng.below(3) {
        0 => StragglerConfig::default(),
        1 => StragglerConfig { frac: 1.0, factor: 16.0 }, // ALL stragglers
        _ => StragglerConfig { frac: rng.uniform(), factor: 1.0 + rng.uniform() * 8.0 },
    };
    ScenarioConfig { partition, participation, straggler }
}

fn tiny_cfg(rng: &mut Pcg) -> (TrainConfig, usize) {
    let schemes = SchemeKind::all();
    let cfg = TrainConfig {
        scheme: schemes[rng.below(schemes.len())],
        num_clients: 2 + rng.below(3),
        rounds: 1,
        tau: 1,
        samples_per_client: 32,
        test_samples: 64,
        scenario: gen_scenario(rng),
        seed: 0xFA11 ^ rng.next_u64(),
        eval_every: 1,
        threads: 1,
        ..Default::default()
    };
    let cut = 1 + rng.below(2);
    (cfg, cut)
}

#[test]
fn degenerate_scenarios_complete_with_finite_renormalized_rounds() {
    let manifest = Manifest::builtin();
    check("degenerate-scenarios", 8, |rng| {
        let (cfg, cut) = tiny_cfg(rng);
        let n = cfg.num_clients;
        let label = format!(
            "{} n={n} cut={cut} [{}]",
            cfg.scheme.name(),
            cfg.scenario.describe()
        );
        let mut trainer =
            Trainer::native(&manifest, cfg).map_err(|e| format!("{label}: construct: {e:#}"))?;
        let stats = trainer.run(cut).map_err(|e| format!("{label}: run: {e:#}"))?;
        prop_assert!(stats.len() == 1, "{label}: expected 1 round, got {}", stats.len());
        let s = &stats[0];
        prop_assert!(s.train_loss.is_finite(), "{label}: non-finite loss {}", s.train_loss);
        prop_assert!(
            (1..=n).contains(&s.participants),
            "{label}: cohort of {} outside 1..={n}",
            s.participants
        );
        let (tl, ta) =
            s.test.ok_or_else(|| format!("{label}: eval round missing test stats"))?;
        prop_assert!(tl.is_finite(), "{label}: non-finite test loss {tl}");
        prop_assert!((0.0..=1.0).contains(&ta), "{label}: accuracy {ta} outside [0, 1]");
        Ok(())
    });
}

#[test]
fn reset_equals_fresh_under_degenerate_scenarios() {
    let manifest = Manifest::builtin();
    check("reset-equals-fresh", 4, |rng| {
        let (cfg, cut) = tiny_cfg(rng);
        let label = format!("{} [{}]", cfg.scheme.name(), cfg.scenario.describe());
        let orig_seed = cfg.seed;
        let reseed = cfg.seed ^ 0xBEEF;

        let mut trainer = Trainer::native(&manifest, cfg.clone())
            .map_err(|e| format!("{label}: construct: {e:#}"))?;
        let first = trainer.run(cut).map_err(|e| format!("{label}: run 1: {e:#}"))?;
        let first = (stats_digest(&first), params_digest(&trainer.global_params(cut)));

        // Reset to a different seed, run, and demand bitwise agreement
        // with a from-scratch trainer at that seed — then reset back and
        // demand the original digests again.
        trainer.reset(reseed);
        let reset_run = trainer.run(cut).map_err(|e| format!("{label}: reset run: {e:#}"))?;
        let reset_run =
            (stats_digest(&reset_run), params_digest(&trainer.global_params(cut)));
        let mut fresh = Trainer::native(&manifest, TrainConfig { seed: reseed, ..cfg })
            .map_err(|e| format!("{label}: fresh construct: {e:#}"))?;
        let fresh_run = fresh.run(cut).map_err(|e| format!("{label}: fresh run: {e:#}"))?;
        let fresh_run = (stats_digest(&fresh_run), params_digest(&fresh.global_params(cut)));
        prop_assert!(reset_run == fresh_run, "{label}: reset({reseed:#x}) != fresh");

        trainer.reset(orig_seed);
        let back = trainer.run(cut).map_err(|e| format!("{label}: reset-back run: {e:#}"))?;
        let back = (stats_digest(&back), params_digest(&trainer.global_params(cut)));
        prop_assert!(back == first, "{label}: reset back to {orig_seed:#x} lost the original run");
        Ok(())
    });
}
