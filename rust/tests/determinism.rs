//! Deterministic-seeding guarantee: two [`Trainer`] runs with the same
//! `TrainConfig { seed, .. }` on the native backend must produce
//! BITWISE-identical eval curves.  This guards the whole seeded stack —
//! `util::rng::Pcg`, `data::generate`/`partition`, `Batcher` ordering,
//! `data::init::init_params`, the channel draws and the backend itself —
//! against accidental nondeterminism (e.g. iteration-order or threading
//! changes).

use sfl_ga::coordinator::{AllocPolicy, SchemeKind, TrainConfig, Trainer};
use sfl_ga::model::Manifest;

/// Full eval curve as raw bits: (round, train_loss, test_loss, test_acc).
fn eval_curve(seed: u64, scheme: SchemeKind) -> Vec<(usize, u64, u64, u64)> {
    let manifest = Manifest::builtin_with_batches(8, 32);
    let cfg = TrainConfig {
        scheme,
        num_clients: 3,
        rounds: 4,
        eval_every: 2,
        samples_per_client: 24,
        test_samples: 32,
        seed,
        alloc: AllocPolicy::Equal,
        ..Default::default()
    };
    let mut t = Trainer::native(&manifest, cfg).unwrap();
    t.run(2)
        .unwrap()
        .into_iter()
        .filter_map(|s| {
            s.test.map(|(tl, ta)| (s.round, s.train_loss.to_bits(), tl.to_bits(), ta.to_bits()))
        })
        .collect()
}

#[test]
fn same_seed_gives_bitwise_identical_eval_curves() {
    for scheme in [SchemeKind::SflGa, SchemeKind::Fl] {
        let a = eval_curve(7, scheme);
        let b = eval_curve(7, scheme);
        assert!(!a.is_empty(), "no eval points recorded");
        assert_eq!(a, b, "{scheme:?}: same seed must reproduce bit-identically");
    }
}

#[test]
fn different_seed_gives_different_curves() {
    let a = eval_curve(7, SchemeKind::SflGa);
    let c = eval_curve(8, SchemeKind::SflGa);
    assert_ne!(a, c, "different seeds should not coincide");
}
