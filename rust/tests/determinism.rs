//! Deterministic-seeding guarantee: two [`Trainer`] runs with the same
//! `TrainConfig { seed, .. }` on the native backend must produce
//! BITWISE-identical eval curves.  This guards the whole seeded stack —
//! `util::rng::Pcg`, `data::generate`/`partition`, `Batcher` ordering,
//! `data::init::init_params`, the channel draws and the backend itself —
//! against accidental nondeterminism (e.g. iteration-order or threading
//! changes).
//!
//! It also pins the parallel round engine's core guarantee: `threads = N`
//! training is bitwise equal to `threads = 1` for EVERY scheme and cut —
//! per-client jobs are pure and all reductions run on the coordinator
//! thread in fixed client-index order over buffered results.  With the
//! pipelined executor this is a strictly stronger statement than it was
//! for the barrier-per-phase engine: at `threads = 4` each participant's
//! client-fwd → server FP+BP (→ unicast client-bwd) runs as one fused
//! chain completing in nondeterministic real-time order, deferred evals
//! interleave with the next round's fan-out on the same workers, and
//! `threads = 1` is the fully serial submit-order schedule — the suites
//! below assert the results never differ by a bit.
//!
//! Two further sources of schedule freedom are covered since the executor
//! grew a persistent worker pool: jobs are dequeued dynamically (any
//! worker may take any job, rather than the old static index striping),
//! and eval calls may split their dense GEMMs into column panels across
//! spare pool capacity (`set_eval_parallelism`).  Both are bitwise-neutral
//! by construction; [`panel_parallel_eval_is_bitwise_equal_to_serial`]
//! pins the maximal panel-split case explicitly.

use sfl_ga::coordinator::{AllocPolicy, SchemeKind, TrainConfig, Trainer};
use sfl_ga::data::partition::Partition;
use sfl_ga::model::{registry, Manifest};
use sfl_ga::scenario::{ScenarioConfig, StragglerConfig};

/// Full eval curve as raw bits: (round, train_loss, test_loss, test_acc).
fn eval_curve(seed: u64, scheme: SchemeKind) -> Vec<(usize, u64, u64, u64)> {
    let manifest = Manifest::builtin_with_batches(8, 32);
    let cfg = TrainConfig {
        scheme,
        num_clients: 3,
        rounds: 4,
        eval_every: 2,
        samples_per_client: 24,
        test_samples: 32,
        seed,
        alloc: AllocPolicy::Equal,
        ..Default::default()
    };
    let mut t = Trainer::native(&manifest, cfg).unwrap();
    t.run(2)
        .unwrap()
        .into_iter()
        .filter_map(|s| {
            s.test.map(|(tl, ta)| (s.round, s.train_loss.to_bits(), tl.to_bits(), ta.to_bits()))
        })
        .collect()
}

#[test]
fn same_seed_gives_bitwise_identical_eval_curves() {
    for scheme in [SchemeKind::SflGa, SchemeKind::Fl] {
        let a = eval_curve(7, scheme);
        let b = eval_curve(7, scheme);
        assert!(!a.is_empty(), "no eval points recorded");
        assert_eq!(a, b, "{scheme:?}: same seed must reproduce bit-identically");
    }
}

#[test]
fn different_seed_gives_different_curves() {
    let a = eval_curve(7, SchemeKind::SflGa);
    let c = eval_curve(8, SchemeKind::SflGa);
    assert_ne!(a, c, "different seeds should not coincide");
}

/// Round stats + final global model as raw bits at a given thread count.
/// `test_samples = 40` with eval batch 32 also exercises the tail batch.
fn run_bits(scheme: SchemeKind, cut: usize, threads: usize) -> (Vec<u64>, Vec<u32>) {
    run_bits_tau(scheme, cut, threads, 1)
}

/// `run_bits` at τ local epochs — τ > 1 exercises the fused chains
/// across consecutive epoch sessions and the τ-averaged loss accounting.
fn run_bits_tau(
    scheme: SchemeKind,
    cut: usize,
    threads: usize,
    tau: usize,
) -> (Vec<u64>, Vec<u32>) {
    let manifest = Manifest::builtin_with_batches(8, 32);
    let cfg = TrainConfig {
        scheme,
        num_clients: 3,
        rounds: 2,
        tau,
        eval_every: 1,
        samples_per_client: 16,
        test_samples: 40,
        seed: 11,
        threads,
        alloc: AllocPolicy::Equal,
        ..Default::default()
    };
    let mut t = Trainer::native(&manifest, cfg).unwrap();
    assert_eq!(t.threads(), threads);
    let mut stat_bits = Vec::new();
    for s in t.run(cut).unwrap() {
        stat_bits.push(s.train_loss.to_bits());
        let (tl, ta) = s.test.expect("eval_every=1 evaluates every round");
        stat_bits.push(tl.to_bits());
        stat_bits.push(ta.to_bits());
    }
    let param_bits: Vec<u32> =
        t.global_params(cut).iter().flatten().map(|v| v.to_bits()).collect();
    (stat_bits, param_bits)
}

#[test]
fn parallel_rounds_are_bitwise_equal_to_serial_for_every_scheme_and_cut() {
    let schemes = [
        SchemeKind::SflGa,
        SchemeKind::SflGaDrift,
        SchemeKind::Sfl,
        SchemeKind::Psl,
        SchemeKind::Fl,
    ];
    for scheme in schemes {
        for cut in 1..=4 {
            let (stats1, params1) = run_bits(scheme, cut, 1);
            let (stats4, params4) = run_bits(scheme, cut, 4);
            assert_eq!(
                stats1, stats4,
                "{scheme:?} cut {cut}: threads=4 round stats diverge from threads=1"
            );
            assert_eq!(
                params1, params4,
                "{scheme:?} cut {cut}: threads=4 final params diverge from threads=1"
            );
        }
    }
}

/// τ = 2 drives each worker chain through two epoch sessions per round
/// and makes FL's fused τ-step local runs meaningfully multi-batch.  One
/// scheme per pipeline shape: broadcast barrier (SflGa), fused unicast
/// client-bwd (Sfl), fused full-model local runs (Fl).
#[test]
fn multi_epoch_pipelined_rounds_are_bitwise_equal_to_serial() {
    for scheme in [SchemeKind::SflGa, SchemeKind::Sfl, SchemeKind::Fl] {
        let (stats1, params1) = run_bits_tau(scheme, 2, 1, 2);
        let (stats4, params4) = run_bits_tau(scheme, 2, 4, 2);
        assert_eq!(
            stats1, stats4,
            "{scheme:?} tau=2: threads=4 round stats diverge from threads=1"
        );
        assert_eq!(
            params1, params4,
            "{scheme:?} tau=2: threads=4 final params diverge from threads=1"
        );
    }
}

/// The pool + panel-parallel eval combination at its extreme: with one
/// full-size eval batch (`test_samples` = eval batch = 32), the trainer
/// folds ALL pool capacity into that single eval call (`eval_par` =
/// `threads`), so every dense layer of the eval forward actually splits
/// into column panels across 4 threads — and the curve must still be
/// bitwise equal to the fully serial run.
#[test]
fn panel_parallel_eval_is_bitwise_equal_to_serial() {
    let run = |threads: usize| -> (Vec<u64>, Vec<u32>) {
        let manifest = Manifest::builtin_with_batches(8, 32);
        let cfg = TrainConfig {
            scheme: SchemeKind::SflGa,
            num_clients: 3,
            rounds: 2,
            eval_every: 1,
            samples_per_client: 16,
            test_samples: 32,
            seed: 17,
            threads,
            alloc: AllocPolicy::Equal,
            ..Default::default()
        };
        let mut t = Trainer::native(&manifest, cfg).unwrap();
        let mut stat_bits = Vec::new();
        for s in t.run(2).unwrap() {
            stat_bits.push(s.train_loss.to_bits());
            let (tl, ta) = s.test.expect("eval_every=1 evaluates every round");
            stat_bits.push(tl.to_bits());
            stat_bits.push(ta.to_bits());
        }
        let param_bits: Vec<u32> =
            t.global_params(2).iter().flatten().map(|v| v.to_bits()).collect();
        (stat_bits, param_bits)
    };
    let (stats1, params1) = run(1);
    let (stats4, params4) = run(4);
    assert_eq!(stats1, stats4, "panel-parallel eval round stats diverge from serial");
    assert_eq!(params1, params4, "panel-parallel eval changed the final params");
}

/// The thread-count guarantee is registry-wide, not builtin-specific:
/// the transformer stack routes every round through the layernorm /
/// softmax-attention / GELU kernels, and its menu cuts sit at block
/// boundaries rather than conv/dense seams.  Same contract: threads=4
/// must reproduce threads=1 bit for bit at every menu cut.  (The
/// threaded CI lane re-runs this whole file under SFLGA_TEST_THREADS=4,
/// so the non-builtin path is exercised there on every PR.)
#[test]
fn transformer_model_rounds_are_bitwise_equal_to_serial() {
    let manifest = registry::manifest_with_batches("txf", 8, 32).unwrap();
    let run = |cut: usize, threads: usize| -> (Vec<u64>, Vec<u32>) {
        let cfg = TrainConfig {
            scheme: SchemeKind::SflGa,
            model: "txf".into(),
            num_clients: 3,
            rounds: 2,
            eval_every: 1,
            samples_per_client: 16,
            test_samples: 40,
            seed: 19,
            threads,
            alloc: AllocPolicy::Equal,
            ..Default::default()
        };
        let mut t = Trainer::native(&manifest, cfg).unwrap();
        assert_eq!(t.threads(), threads);
        let mut stat_bits = Vec::new();
        for s in t.run(cut).unwrap() {
            stat_bits.push(s.train_loss.to_bits());
            let (tl, ta) = s.test.expect("eval_every=1 evaluates every round");
            stat_bits.push(tl.to_bits());
            stat_bits.push(ta.to_bits());
        }
        let param_bits: Vec<u32> =
            t.global_params(cut).iter().flatten().map(|v| v.to_bits()).collect();
        (stat_bits, param_bits)
    };
    for cut in manifest.for_dataset("mnist").unwrap().menu().ids() {
        let (stats1, params1) = run(cut, 1);
        let (stats4, params4) = run(cut, 4);
        assert_eq!(stats1, stats4, "txf cut {cut}: threads=4 stats diverge from threads=1");
        assert_eq!(params1, params4, "txf cut {cut}: threads=4 params diverge from threads=1");
    }
}

/// Round stats + final global model as raw bits for a full scenario run:
/// Dirichlet(0.3) label skew, participation 0.5 (cohort of 2 of 4
/// clients) and a 4× straggler — the heterogeneity path must keep the
/// same bitwise thread-count independence as the IID path.
fn run_bits_scenario(scheme: SchemeKind, threads: usize) -> (Vec<u64>, Vec<u32>) {
    let manifest = Manifest::builtin_with_batches(8, 32);
    let cfg = TrainConfig {
        scheme,
        num_clients: 4,
        rounds: 3,
        eval_every: 1,
        samples_per_client: 16,
        test_samples: 40,
        seed: 13,
        threads,
        alloc: AllocPolicy::Equal,
        scenario: ScenarioConfig {
            partition: Partition::Dirichlet(0.3),
            participation: 0.5,
            straggler: StragglerConfig { frac: 0.25, factor: 4.0 },
        },
        ..Default::default()
    };
    let cut = 2;
    let mut t = Trainer::native(&manifest, cfg).unwrap();
    assert_eq!(t.threads(), threads);
    let mut stat_bits = Vec::new();
    for s in t.run(cut).unwrap() {
        assert_eq!(s.participants, 2, "participation 0.5 of 4 clients must pick 2");
        stat_bits.push(s.train_loss.to_bits());
        stat_bits.push(s.comm.total_bits().to_bits());
        stat_bits.push(s.latency.total().to_bits());
        let (tl, ta) = s.test.expect("eval_every=1 evaluates every round");
        stat_bits.push(tl.to_bits());
        stat_bits.push(ta.to_bits());
    }
    let param_bits: Vec<u32> =
        t.global_params(cut).iter().flatten().map(|v| v.to_bits()).collect();
    (stat_bits, param_bits)
}

#[test]
fn scenario_rounds_are_bitwise_equal_to_serial_for_every_scheme() {
    let schemes = [
        SchemeKind::SflGa,
        SchemeKind::SflGaDrift,
        SchemeKind::Sfl,
        SchemeKind::Psl,
        SchemeKind::Fl,
    ];
    for scheme in schemes {
        let (stats1, params1) = run_bits_scenario(scheme, 1);
        let (stats4, params4) = run_bits_scenario(scheme, 4);
        assert_eq!(
            stats1, stats4,
            "{scheme:?}: scenario threads=4 round stats diverge from threads=1"
        );
        assert_eq!(
            params1, params4,
            "{scheme:?}: scenario threads=4 final params diverge from threads=1"
        );
    }
}
