//! Chaos regression suite (DESIGN.md §Transport): real `sfl-participant`
//! processes with injected faults, asserting the coordinator's
//! drop-renormalize-restart policy produces *exactly* the run it claims
//! to — not merely "a" completed run.
//!
//! * kill a participant mid-round → the completed run is bitwise the run
//!   that excluded that client up front (per-client state is keyed by
//!   `(seed, id)`, so the survivor federation is self-contained);
//! * delay below the deadline (SIGSTOP bursts) → bitwise no-op;
//! * packet loss on one peer's responses → deadline fault → same
//!   excluded-up-front equality;
//! * SIGKILL a participant, relaunch it, let it rejoin → bitwise the
//!   loopback run driven by the same churn trace;
//! * SIGKILL the **coordinator** mid-run, relaunch with `--resume` →
//!   bitwise the uninterrupted run;
//! * end-to-end smoke of the two binaries over localhost TCP.

mod chaos_harness;

use std::net::TcpListener;
use std::process::Command;
use std::time::Duration;

#[cfg(unix)]
use chaos_harness::signal;
use chaos_harness::{spawn_participant, spawn_participant_with, ChaosProxy, ProcGuard, Watchdog};
use sfl_ga::coordinator::{params_digest, stats_digest, NetTrainer, SchemeKind, TrainConfig};
use sfl_ga::model::Manifest;
use sfl_ga::runtime::TcpTransport;
use sfl_ga::scenario::ChurnTrace;

fn cfg(scheme: SchemeKind, n: usize) -> TrainConfig {
    TrainConfig {
        scheme,
        num_clients: n,
        rounds: 2,
        tau: 1,
        samples_per_client: 32,
        test_samples: 64,
        seed: 17,
        eval_every: 1,
        threads: 1,
        ..Default::default()
    }
}

/// Digest-pair of the loopback run over `n` participants — the oracle
/// every faulted TCP run must land on.
fn loopback_digests(scheme: SchemeKind, n: usize, cut: usize) -> (u64, u64) {
    let manifest = Manifest::builtin();
    let mut nt = NetTrainer::loopback(&manifest, cfg(scheme, n), n).expect("loopback");
    let stats = nt.run(cut).expect("loopback run");
    (stats_digest(&stats), params_digest(&nt.global_params(cut)))
}

/// Rendezvous `n` spawned participants on an ephemeral listener; the
/// address comes back too so churn tests can relaunch participants at it.
fn federation(n: u64) -> (Vec<ProcGuard>, TcpTransport, String) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let participants: Vec<ProcGuard> = (0..n).map(|id| spawn_participant(&addr, id)).collect();
    let transport =
        TcpTransport::accept(listener, n as usize, Duration::from_secs(30)).expect("rendezvous");
    assert_eq!(transport.joined(), (0..n).collect::<Vec<_>>());
    (participants, transport, addr)
}

#[test]
fn kill_mid_round_equals_excluded_up_front() {
    let _wd = Watchdog::arm("kill_mid_round_equals_excluded_up_front", Duration::from_secs(180));
    let cut = 2;
    let manifest = Manifest::builtin();
    let (mut participants, transport, _addr) = federation(3);
    let mut nt =
        NetTrainer::new(&manifest, cfg(SchemeKind::SflGa, 3), Duration::from_secs(60), transport)
            .expect("net trainer");
    // Let participant 2 finish its rendezvous (it prints JOINED after
    // processing Welcome), then SIGKILL it — its death surfaces inside
    // round 0's forward collection as a Gone event.
    participants[2].wait_for_line("JOINED 2", Duration::from_secs(30));
    participants[2].kill();

    let stats = nt.run(cut).expect("run completes despite the kill");
    assert_eq!(nt.dropped(), &[2], "fault policy should have dropped exactly client 2");
    assert_eq!(nt.live(), vec![0, 1]);
    let faulted = (stats_digest(&stats), params_digest(&nt.global_params(cut)));
    nt.shutdown();

    // Per-client channel/capacity draws are keyed by (seed, id), not by
    // the population size, so the 2-survivor federation must be bitwise
    // the federation that never had client 2.
    assert_eq!(
        faulted,
        loopback_digests(SchemeKind::SflGa, 2, cut),
        "survivor run diverged from the excluded-up-front run"
    );
}

#[cfg(unix)] // SIGSTOP/SIGCONT straggler injection
#[test]
fn delay_below_deadline_is_bitwise_noop() {
    let _wd = Watchdog::arm("delay_below_deadline_is_bitwise_noop", Duration::from_secs(180));
    let cut = 1;
    let manifest = Manifest::builtin();
    let (participants, transport, _addr) = federation(2);
    let mut nt =
        NetTrainer::new(&manifest, cfg(SchemeKind::SflGa, 2), Duration::from_secs(120), transport)
            .expect("net trainer");

    // Straggle participant 0 in SIGSTOP bursts while the run progresses:
    // well below the deadline, so nothing may change — not one bit.
    let pid = participants[0].pid();
    let injector = std::thread::spawn(move || {
        for _ in 0..3 {
            signal(pid, "STOP");
            std::thread::sleep(Duration::from_millis(300));
            signal(pid, "CONT");
            std::thread::sleep(Duration::from_millis(100));
        }
    });
    let stats = nt.run(cut).expect("run completes under delay");
    injector.join().expect("injector thread");
    assert!(nt.dropped().is_empty(), "sub-deadline delay must not drop anyone");
    let delayed = (stats_digest(&stats), params_digest(&nt.global_params(cut)));
    nt.shutdown();

    assert_eq!(
        delayed,
        loopback_digests(SchemeKind::SflGa, 2, cut),
        "sub-deadline delay changed the run"
    );
}

#[test]
fn packet_loss_triggers_deadline_drop() {
    let _wd = Watchdog::arm("packet_loss_triggers_deadline_drop", Duration::from_secs(180));
    let cut = 2;
    let manifest = Manifest::builtin();
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    // Participants 0 and 1 connect directly; 2 sits behind a proxy that
    // forwards its Join and then black-holes every later response while
    // keeping the connection alive — pure response loss, no EOF signal,
    // so only the deadline can catch it.
    let direct: Vec<ProcGuard> = (0..2).map(|id| spawn_participant(&addr, id)).collect();
    let proxy = ChaosProxy::start(addr, 1);
    let lossy = spawn_participant(&proxy.addr, 2);
    let transport =
        TcpTransport::accept(listener, 3, Duration::from_secs(30)).expect("rendezvous");
    assert_eq!(transport.joined(), vec![0, 1, 2]);

    // SFL exercises the per-client replica path: dropping 2 must also
    // retire its model replica, leaving a 2-replica FedAvg.
    let mut nt =
        NetTrainer::new(&manifest, cfg(SchemeKind::Sfl, 3), Duration::from_secs(3), transport)
            .expect("net trainer");
    let stats = nt.run(cut).expect("run completes despite response loss");
    assert_eq!(nt.dropped(), &[2], "the lossy peer should time out and drop");
    let faulted = (stats_digest(&stats), params_digest(&nt.global_params(cut)));
    nt.shutdown();
    drop(direct);
    drop(lossy);

    assert_eq!(
        faulted,
        loopback_digests(SchemeKind::Sfl, 2, cut),
        "post-drop run diverged from the excluded-up-front run"
    );
}

#[test]
fn kill_restart_rejoin_matches_churn_oracle() {
    let _wd = Watchdog::arm("kill_restart_rejoin_matches_churn_oracle", Duration::from_secs(240));
    let cut = 2;
    let manifest = Manifest::builtin();
    // SFL keeps per-client replicas, so the rejoin must also install the
    // cold replica — the strictest client-state path.
    let mut c = cfg(SchemeKind::Sfl, 3);
    c.rounds = 4;
    let (mut participants, transport, addr) = federation(3);
    let mut nt = NetTrainer::new(&manifest, c.clone(), Duration::from_secs(60), transport)
        .expect("net trainer");
    participants[1].wait_for_line("JOINED 1", Duration::from_secs(30));

    // Round 1: the full cohort.
    nt.step(cut).expect("round 1").expect("not done");
    assert_eq!(nt.live(), vec![0, 1, 2]);

    // SIGKILL participant 1 between rounds: its death surfaces as a Gone
    // inside round 2, which completes over the survivors.
    participants[1].kill();
    nt.step(cut).expect("round 2").expect("not done");
    assert_eq!(nt.dropped(), &[1], "the killed peer should have been dropped");
    assert_eq!(nt.live(), vec![0, 2]);

    // Relaunch it as a brand-new process.  Admission only happens at a
    // round boundary; await it HERE so the rejoin round is pinned and the
    // oracle trace below is exact.
    participants[1] = spawn_participant(&addr, 1);
    nt.await_peer(1, Duration::from_secs(30)).expect("rejoin admitted");
    assert_eq!(nt.live(), vec![0, 1, 2]);
    nt.step(cut).expect("round 3").expect("not done");
    nt.step(cut).expect("round 4").expect("not done");
    assert!(nt.step(cut).expect("past the end").is_none());
    let churned = (stats_digest(nt.stats()), params_digest(&nt.global_params(cut)));
    nt.shutdown();

    // Oracle: the same churn trace through the loopback engine — leave at
    // entry of round index 1, cold rejoin at entry of round index 2.
    let mut oracle = NetTrainer::loopback(&manifest, c, 3).expect("loopback");
    let trace = ChurnTrace::parse("1:-1,2:+1").expect("trace");
    let stats = oracle.run_churn(cut, &trace).expect("oracle run");
    assert_eq!(
        churned,
        (stats_digest(&stats), params_digest(&oracle.global_params(cut))),
        "kill/relaunch TCP run diverged from the churn-trace oracle"
    );
}

/// CLI for the checkpoint/resume scenario: both coordinator launches must
/// agree on every training-relevant flag or `--resume` refuses the file.
fn coordinator_cmd(extra: &[&str]) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sfl-coordinator"));
    cmd.args([
        "--clients", "2",
        "--rounds", "4",
        "--tau", "1",
        "--samples-per-client", "16",
        "--test-samples", "64",
        "--eval-every", "1",
        "--threads", "1",
        "--scheme", "sfl",
        "--cut", "2",
        "--seed", "17",
    ]);
    cmd.args(extra);
    cmd
}

#[test]
fn coordinator_sigkill_resume_matches_uninterrupted() {
    let _wd =
        Watchdog::arm("coordinator_sigkill_resume_matches_uninterrupted", Duration::from_secs(300));

    // Baseline: one uninterrupted binary run, COMPLETE line captured.
    let mut baseline = ProcGuard::spawn("coordinator-baseline", &mut coordinator_cmd(&[]));
    let listening = baseline.wait_for_line("LISTENING ", Duration::from_secs(60));
    let addr = listening.trim_start_matches("LISTENING ").trim().to_string();
    let _baseline_parts: Vec<ProcGuard> =
        (0..2).map(|id| spawn_participant(&addr, id)).collect();
    baseline.wait_for_line("JOINED ", Duration::from_secs(30));
    let want = baseline.wait_for_line("COMPLETE ", Duration::from_secs(120));
    baseline.wait_success(Duration::from_secs(30));

    // Chaos run: checkpoint every round, SIGKILL right after the first
    // checkpoint lands, relaunch with --resume on the SAME address.
    let dir = std::env::temp_dir().join(format!("sfl-resume-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("checkpoint dir");
    let ckpt = dir.join("run.ckpt");
    let ckpt_s = ckpt.to_str().expect("utf8 path").to_string();
    let mut coord = ProcGuard::spawn(
        "coordinator-a",
        &mut coordinator_cmd(&["--checkpoint", &ckpt_s, "--checkpoint-every", "1"]),
    );
    let listening = coord.wait_for_line("LISTENING ", Duration::from_secs(60));
    let addr = listening.trim_start_matches("LISTENING ").trim().to_string();
    // Participants armed for reconnect: on the coordinator's death they
    // see EOF, re-arm the dialer and open their next session with Rejoin.
    let participants: Vec<ProcGuard> = (0..2)
        .map(|id| {
            spawn_participant_with(
                &addr,
                id,
                &["--reconnect", "--reconnect-window-ms", "120000"],
            )
        })
        .collect();
    coord.wait_for_line("JOINED ", Duration::from_secs(30));
    coord.wait_for_line("CHECKPOINT ", Duration::from_secs(120));
    coord.kill(); // SIGKILL: no shutdown handshake, in-flight round lost

    let mut resumed = ProcGuard::spawn(
        "coordinator-b",
        &mut coordinator_cmd(&[
            "--listen", &addr,
            "--resume", &ckpt_s,
            "--checkpoint", &ckpt_s,
            "--checkpoint-every", "1",
        ]),
    );
    let joined = resumed.wait_for_line("JOINED ", Duration::from_secs(60));
    assert_eq!(joined, "JOINED 0 1", "both survivors should rejoin the resumed coordinator");
    let got = resumed.wait_for_line("COMPLETE ", Duration::from_secs(120));
    resumed.wait_success(Duration::from_secs(30));
    drop(participants);
    let _ = std::fs::remove_dir_all(&dir);

    // Round history, drop set and digests — the whole line — must match.
    assert_eq!(got, want, "resumed run diverged from the uninterrupted run");
}

#[test]
fn multiprocess_binaries_complete_a_run() {
    let _wd = Watchdog::arm("multiprocess_binaries_complete_a_run", Duration::from_secs(180));
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sfl-coordinator"));
    cmd.args([
        "--listen", "127.0.0.1:0",
        "--clients", "2",
        "--rounds", "1",
        "--tau", "1",
        "--samples-per-client", "16",
        "--test-samples", "64",
        "--eval-every", "1",
        "--threads", "1",
        "--scheme", "sfl-ga",
        "--cut", "2",
    ]);
    let mut coordinator = ProcGuard::spawn("coordinator", &mut cmd);
    let listening = coordinator.wait_for_line("LISTENING ", Duration::from_secs(60));
    let addr = listening.trim_start_matches("LISTENING ").trim();

    let _participants: Vec<ProcGuard> =
        (0..2).map(|id| spawn_participant(addr, id)).collect();
    let joined = coordinator.wait_for_line("JOINED ", Duration::from_secs(30));
    assert_eq!(joined, "JOINED 0 1");
    let complete = coordinator.wait_for_line("COMPLETE ", Duration::from_secs(120));
    assert!(
        complete.contains("rounds=1") && complete.contains("dropped=-"),
        "unexpected completion line: {complete}"
    );
    coordinator.wait_success(Duration::from_secs(30));
}
