//! Ablation tests: the two readings of SFL-GA's client update (shared w^c
//! per eq 19 vs literal per-client drift) and heterogeneous client compute
//! (per-client constraint 30b).  All run on the native backend + built-in
//! manifest.

use sfl_ga::coordinator::timing::{AllocPolicy, round_latency};
use sfl_ga::coordinator::{SchemeKind, TrainConfig, Trainer};
use sfl_ga::latency::ComputeConfig;
use sfl_ga::model::Manifest;
use sfl_ga::wireless::{Channel, NetConfig};

#[test]
fn drift_scheme_parses_and_is_not_in_paper_set() {
    assert_eq!(SchemeKind::parse("sfl-ga-drift").unwrap(), SchemeKind::SflGaDrift);
    assert!(!SchemeKind::all().contains(&SchemeKind::SflGaDrift));
}

/// The drift ablation exchanges exactly what SFL-GA exchanges.
#[test]
fn drift_comm_equals_sfl_ga() {
    let manifest = Manifest::builtin();
    let spec = manifest.for_dataset("mnist").unwrap();
    let comp = ComputeConfig::default();
    let comm = |scheme: SchemeKind, v: usize| {
        sfl_ga::coordinator::comm::round_comm(scheme, spec, spec.cut(v), &comp, 10, 1)
    };
    for v in 1..=4 {
        assert_eq!(comm(SchemeKind::SflGa, v), comm(SchemeKind::SflGaDrift, v));
    }
}

/// At small cuts the two readings nearly coincide; the drift variant
/// actually drifts (nonzero replica divergence) while SFL-GA does not.
#[test]
fn drift_ablation_diverges_where_sfl_ga_does_not() {
    let manifest = Manifest::builtin_with_batches(8, 32);
    let run = |scheme: SchemeKind| {
        let cfg = TrainConfig {
            scheme,
            num_clients: 4,
            rounds: 2,
            eval_every: 10,
            samples_per_client: 24,
            test_samples: 32,
            alloc: AllocPolicy::Equal,
            seed: 5,
            ..Default::default()
        };
        let mut t = Trainer::native(&manifest, cfg).unwrap();
        t.run(2).unwrap();
        t.client_drift(2)
    };
    assert_eq!(run(SchemeKind::SflGa), 0.0);
    assert!(run(SchemeKind::SflGaDrift) > 0.0);
}

// ------------------------------------------------- heterogeneous clients

#[test]
fn client_flops_homogeneous_by_default() {
    let comp = ComputeConfig::default();
    let f = comp.client_flops(5, 1);
    assert!(f.iter().all(|&x| x == comp.f_client_max));
}

#[test]
fn client_flops_spread_is_bounded_and_deterministic() {
    let comp = ComputeConfig { f_client_spread: 0.5, ..Default::default() };
    let f1 = comp.client_flops(10, 10);
    let f2 = comp.client_flops(10, 10);
    assert_eq!(f1, f2, "deployment draw must be stable");
    for &f in &f1 {
        assert!(f <= comp.f_client_max && f >= 0.5 * comp.f_client_max);
    }
    assert!(f1.windows(2).any(|w| w[0] != w[1]), "spread should differ across clients");
}

/// Heterogeneity can only slow the round down (straggler effect), and the
/// optimal allocator partially compensates relative to equal split.
#[test]
fn heterogeneity_slows_rounds_and_allocator_compensates() {
    let manifest = Manifest::builtin();
    let spec = manifest.for_dataset("mnist").unwrap().clone();
    let net = NetConfig::default();
    let mut ch = Channel::new(net.clone(), 10, 3);
    let st = ch.draw_round();
    let homo = ComputeConfig::default();
    let hetero = ComputeConfig { f_client_spread: 0.6, ..Default::default() };
    let cut = spec.cut(2);
    let lat = |comp: &ComputeConfig, policy: AllocPolicy| {
        round_latency(SchemeKind::SflGa, &spec, cut, &net, comp, &st, policy, 1)
    };

    let l_homo = lat(&homo, AllocPolicy::Equal);
    let l_het_eq = lat(&hetero, AllocPolicy::Equal);
    let l_het_opt = lat(&hetero, AllocPolicy::Optimal);

    assert!(
        l_het_eq.total() > l_homo.total(),
        "straggler must slow the round: {} vs {}",
        l_het_eq.total(),
        l_homo.total()
    );
    assert!(
        l_het_opt.uplink_leg <= l_het_eq.uplink_leg * (1.0 + 1e-9),
        "optimal allocation must not be worse under heterogeneity"
    );
}
