//! Virtual-population contracts (DESIGN.md §Population):
//!
//! 1. The lazy O(cohort) path (`Trainer::run`) is BITWISE the dense
//!    policy path (`draw_channel` + `run_round`) at a population size
//!    where materializing anything per-client would be visible — both
//!    evaluate the same keyed pure functions, restricted to the cohort.
//! 2. Derivation order cannot matter: querying population facts in any
//!    interleaving (scattered clients, later draws first) never perturbs
//!    a subsequent training run — per-client state is a pure function of
//!    `(run_seed, client_id)`, not of what was derived before it.
//! 3. Resident per-round population state is O(cohort): its peak is a
//!    function of the cohort size alone, equal across population sizes
//!    that differ by 10× (the bound `benches/bench_population.rs` then
//!    pushes to N = 10⁶).
//! 4. Schemes that inherently keep one model replica per client reject
//!    populations past `MAX_PER_CLIENT_REPLICAS` instead of allocating.

use sfl_ga::coordinator::trainer::MAX_PER_CLIENT_REPLICAS;
use sfl_ga::coordinator::{AllocPolicy, SchemeKind, TrainConfig, Trainer};
use sfl_ga::data::partition::Partition;
use sfl_ga::model::Manifest;
use sfl_ga::scenario::{ScenarioConfig, StragglerConfig};

fn manifest() -> Manifest {
    Manifest::builtin_with_batches(8, 32)
}

/// N-client config at participation `part` — small per-round work however
/// large N is (the cohort is what gets materialized).
fn pop_cfg(num_clients: usize, part: f64, rounds: usize) -> TrainConfig {
    TrainConfig {
        scheme: SchemeKind::SflGa,
        num_clients,
        rounds,
        eval_every: rounds,
        samples_per_client: 16,
        test_samples: 32,
        seed: 23,
        threads: 1,
        alloc: AllocPolicy::Equal,
        scenario: ScenarioConfig {
            partition: Partition::Dirichlet(0.3),
            participation: part,
            straggler: StragglerConfig { frac: 0.1, factor: 4.0 },
        },
        ..Default::default()
    }
}

/// Everything a run observes, as raw bits.
fn fingerprint(stats: &[sfl_ga::coordinator::RoundStats], t: &Trainer, cut: usize) -> Vec<u64> {
    let mut bits = Vec::new();
    for s in stats {
        bits.push(s.participants as u64);
        bits.push(s.train_loss.to_bits());
        bits.push(s.comm.total_bits().to_bits());
        bits.push(s.latency.total().to_bits());
        if let Some((tl, ta)) = s.test {
            bits.push(tl.to_bits());
            bits.push(ta.to_bits());
        }
    }
    bits.extend(t.global_params(cut).iter().flatten().map(|v| u64::from(v.to_bits())));
    bits
}

/// Contract 1: at N = 10_000 the lazy cohort-only derivation inside
/// `run` agrees bitwise with the dense `draw_channel` + `run_round`
/// policy loop (which materializes all 10_000 gains per round and then
/// restricts them to the cohort).
#[test]
fn lazy_cohort_run_matches_dense_policy_loop_bitwise_at_10k_clients() {
    let n = 10_000;
    let rounds = 2;
    // participation 1e-3 → cohort of exactly ⌈10⌉ clients per round.
    let mut lazy = Trainer::native(&manifest(), pop_cfg(n, 1e-3, rounds)).unwrap();
    let lazy_stats = lazy.run(2).unwrap();
    assert!(lazy_stats.iter().all(|s| s.participants == 10));

    let mut dense = Trainer::native(&manifest(), pop_cfg(n, 1e-3, rounds)).unwrap();
    let mut dense_stats = Vec::new();
    for _ in 0..rounds {
        let state = dense.draw_channel();
        assert_eq!(state.gains.len(), n, "the policy surface is the dense channel");
        dense_stats.push(dense.run_round(2, &state).unwrap());
    }
    assert_eq!(
        fingerprint(&lazy_stats, &lazy, 2),
        fingerprint(&dense_stats, &dense, 2),
        "lazy cohort derivation diverges from the dense channel restriction"
    );
}

/// Contract 2: deriving population facts out of order — scattered client
/// ids, future channel draws, future cohorts, all BEFORE training — is
/// invisible to the run.  (Stateful streams would fail this: any query
/// would advance them.)
#[test]
fn derivation_order_is_invisible_to_training() {
    let n = 10_000;
    let mut plain = Trainer::native(&manifest(), pop_cfg(n, 1e-3, 2)).unwrap();
    let a = {
        let s = plain.run(2).unwrap();
        fingerprint(&s, &plain, 2)
    };

    let mut probed = Trainer::native(&manifest(), pop_cfg(n, 1e-3, 2)).unwrap();
    {
        let pop = probed.population();
        // Scattered, repeated, reversed: capacities and gains for clients
        // the run may or may not touch, future draws before past ones.
        for &i in &[9_999u64, 0, 4_821, 77, 9_999, 3] {
            let _ = pop.capacity(i);
            let _ = pop.gain_at(42, i);
            let _ = pop.gain_at(0, i);
            let _ = pop.is_straggler(i);
        }
        let _ = pop.cohort(17);
        let _ = pop.cohort(0);
        let _ = pop.caps_dense();
    }
    let b = {
        let s = probed.run(2).unwrap();
        fingerprint(&s, &probed, 2)
    };
    assert_eq!(a, b, "probing the population perturbed the training run");
}

/// Contract 3: peak resident population state is a function of the
/// cohort, not of N — equal bytes for equal cohorts at N and 10·N.
#[test]
fn peak_resident_state_depends_on_cohort_not_population() {
    let run_peak = |n: usize, part: f64| {
        let mut t = Trainer::native(&manifest(), pop_cfg(n, part, 1)).unwrap();
        let stats = t.run(2).unwrap();
        (stats[0].participants, t.peak_resident_population_bytes())
    };
    // Same cohort K = 50 from populations 10× apart.
    let (k_small, peak_small) = run_peak(1_000, 0.05);
    let (k_big, peak_big) = run_peak(10_000, 0.005);
    assert_eq!(k_small, 50);
    assert_eq!(k_big, 50);
    assert_eq!(
        peak_small, peak_big,
        "peak resident population state must depend on the cohort only"
    );
    assert!(peak_small > 0, "peak accounting never ran");
    // A bigger cohort from the SAME population costs more.
    let (k2, peak2) = run_peak(1_000, 0.1);
    assert_eq!(k2, 100);
    assert!(peak2 > peak_small, "resident state must scale with the cohort");
}

/// Contract 4: per-replica schemes are bounded, with a clear error —
/// and the bound is checked before any O(N) allocation happens.
#[test]
fn per_replica_schemes_reject_oversized_populations() {
    for scheme in [SchemeKind::Sfl, SchemeKind::Psl, SchemeKind::SflGaDrift] {
        let cfg = TrainConfig {
            scheme,
            num_clients: MAX_PER_CLIENT_REPLICAS + 1,
            ..pop_cfg(4, 1e-3, 1)
        };
        let err = Trainer::native(&manifest(), cfg)
            .err()
            .expect("oversized per-replica population must be rejected")
            .to_string();
        assert!(
            err.contains("replica per client"),
            "{scheme:?}: unexpected error: {err}"
        );
    }
    // The shared-model schemes take the same population in stride.
    for scheme in [SchemeKind::SflGa, SchemeKind::Fl] {
        let cfg = TrainConfig {
            scheme,
            num_clients: MAX_PER_CLIENT_REPLICAS + 1,
            ..pop_cfg(4, 1e-3, 1)
        };
        assert!(Trainer::native(&manifest(), cfg).is_ok(), "{scheme:?} must scale past the bound");
    }
}
