//! Scenario-engine integration tests: partition statistics flowing into
//! sample-count-weighted aggregation, partial participation flowing into
//! the communication/latency accounting, and straggler compute profiles
//! flowing into the timing model — plus the backward-compatibility
//! guarantee that the default scenario reproduces the pre-scenario
//! (IID, homogeneous, always-on) behavior exactly.

use sfl_ga::coordinator::{AllocPolicy, SchemeKind, TrainConfig, Trainer};
use sfl_ga::data::partition::{label_marginals, Partition};
use sfl_ga::data::{generate, partition};
use sfl_ga::model::Manifest;
use sfl_ga::scenario::{ScenarioConfig, StragglerConfig};
use sfl_ga::tensor;

fn manifest() -> Manifest {
    Manifest::builtin_with_batches(8, 32)
}

fn base_cfg(scheme: SchemeKind) -> TrainConfig {
    TrainConfig {
        scheme,
        num_clients: 4,
        rounds: 2,
        eval_every: 2,
        samples_per_client: 16,
        test_samples: 32,
        seed: 19,
        alloc: AllocPolicy::Equal,
        ..Default::default()
    }
}

/// The legacy `data::partition` wrapper and the strategy API must agree
/// exactly — this is what makes `--partition iid` (the default) reproduce
/// pre-scenario runs byte-for-byte.
#[test]
fn partition_wrapper_matches_strategy_api() {
    let spec = manifest().for_dataset("mnist").unwrap().clone();
    let ds = generate(&spec, "mnist", 300, 5);
    assert_eq!(
        partition(&ds, 6, None, 9),
        Partition::Iid.indices(&ds.labels, ds.classes, 6, 9)
    );
    assert_eq!(
        partition(&ds, 6, Some(0.3), 9),
        Partition::Dirichlet(0.3).indices(&ds.labels, ds.classes, 6, 9)
    );
}

/// Full coverage + non-empty shards for every strategy on real generated
/// data, and the label marginals behave as the strategy promises.
#[test]
fn partition_statistics_on_generated_data() {
    let spec = manifest().for_dataset("mnist").unwrap().clone();
    let ds = generate(&spec, "mnist", 600, 7);
    for p in [Partition::Iid, Partition::Dirichlet(0.2), Partition::Shards(2)] {
        let shards = p.indices(&ds.labels, ds.classes, 6, 11);
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..600).collect::<Vec<_>>(), "{}: not a full cover", p.name());
        assert!(shards.iter().all(|s| !s.is_empty()));
    }
    // Dirichlet(0.2) skews at least one client visibly past IID's ~0.1.
    let skewed = Partition::Dirichlet(0.2).indices(&ds.labels, ds.classes, 6, 11);
    let max_marginal = skewed
        .iter()
        .map(|s| label_marginals(&ds.labels, ds.classes, s).into_iter().fold(0.0f64, f64::max))
        .fold(0.0f64, f64::max);
    assert!(max_marginal > 0.3, "no visible skew: max marginal {max_marginal}");
}

/// Size-weighted FedAvg: aggregating with ρ^n = |D^n|/|D| weights must
/// equal the hand-computed weighted mean (the reduction the trainer runs
/// in fixed client-index order).
#[test]
fn size_weighted_fedavg_matches_manual_mean() {
    // Two clients with 1 and 3 samples → ρ = [0.25, 0.75].
    let sizes = [1usize, 3];
    let total: usize = sizes.iter().sum();
    let rho: Vec<f64> = sizes.iter().map(|&s| s as f64 / total as f64).collect();
    let a: Vec<Vec<f32>> = vec![vec![1.0, -2.0], vec![4.0]];
    let b: Vec<Vec<f32>> = vec![vec![3.0, 6.0], vec![-4.0]];
    let agg = tensor::weighted_sum(&[&a, &b], &rho);
    assert_eq!(agg[0], vec![0.25 * 1.0 + 0.75 * 3.0, 0.25 * -2.0 + 0.75 * 6.0]);
    assert_eq!(agg[1], vec![0.25 * 4.0 + 0.75 * -4.0]);
}

/// The trainer's ρ weights come from the partition sizes and sum to 1.
#[test]
fn trainer_rho_tracks_partition_sizes() {
    let mut cfg = base_cfg(SchemeKind::SflGa);
    cfg.scenario.partition = Partition::Dirichlet(0.3);
    let t = Trainer::native(&manifest(), cfg).unwrap();
    let rho = t.rho();
    assert_eq!(rho.len(), 4);
    assert!((rho.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    assert!(rho.iter().all(|&r| r > 0.0), "empty shard slipped through: {rho:?}");
}

/// Partial participation shrinks the cohort AND the accounted traffic:
/// comm volume scales with who actually uploaded, and the cohort size is
/// recorded in the round stats.
#[test]
fn participation_shrinks_comm_and_is_recorded() {
    let run = |participation: f64| {
        let mut cfg = base_cfg(SchemeKind::SflGa);
        cfg.scenario.participation = participation;
        let mut t = Trainer::native(&manifest(), cfg).unwrap();
        t.run(2).unwrap()
    };
    let full = run(1.0);
    let half = run(0.5);
    assert!(full.iter().all(|s| s.participants == 4));
    assert!(half.iter().all(|s| s.participants == 2));
    for (f, h) in full.iter().zip(&half) {
        assert!(
            h.comm.total_bits() < f.comm.total_bits(),
            "cohort of 2 must move fewer bits than cohort of 4"
        );
    }
    // SFL-GA uplink is per-participant: half the cohort, half the upload.
    assert!((half[0].comm.uplink_bits - full[0].comm.uplink_bits / 2.0).abs() < 1e-6);
}

/// Straggler profiles slow the simulated round down (the slowest cohort
/// member gates the computation legs) without changing the traffic.
#[test]
fn stragglers_increase_latency_not_comm() {
    let run = |straggler: StragglerConfig| {
        let mut cfg = base_cfg(SchemeKind::SflGa);
        cfg.scenario.straggler = straggler;
        let mut t = Trainer::native(&manifest(), cfg).unwrap();
        t.run(2).unwrap()
    };
    let plain = run(StragglerConfig::default());
    let slow = run(StragglerConfig { frac: 0.5, factor: 8.0 });
    for (p, s) in plain.iter().zip(&slow) {
        assert_eq!(p.comm.total_bits(), s.comm.total_bits(), "stragglers must not change bits");
        assert!(
            s.latency.total() > p.latency.total(),
            "8x stragglers must slow the round: {} vs {}",
            s.latency.total(),
            p.latency.total()
        );
    }
}

/// The explicit default scenario is the pre-scenario behavior: spelling
/// out `iid + participation 1.0 + no stragglers` changes nothing, and
/// training results are identical to the implicit default.
#[test]
fn default_scenario_is_identity() {
    let curve = |scenario: ScenarioConfig| {
        let mut cfg = base_cfg(SchemeKind::SflGa);
        cfg.scenario = scenario;
        let mut t = Trainer::native(&manifest(), cfg).unwrap();
        t.run(2)
            .unwrap()
            .into_iter()
            .map(|s| {
                (
                    s.participants,
                    s.train_loss.to_bits(),
                    s.comm.total_bits().to_bits(),
                    s.latency.total().to_bits(),
                )
            })
            .collect::<Vec<_>>()
    };
    let implicit = curve(ScenarioConfig::default());
    let explicit = curve(ScenarioConfig {
        partition: Partition::Iid,
        participation: 1.0,
        straggler: StragglerConfig { frac: 0.0, factor: 1.0 },
    });
    assert_eq!(implicit, explicit);
    assert!(implicit.iter().all(|&(k, ..)| k == 4), "everyone participates by default");
}

/// Scenario configs are validated at trainer construction.
#[test]
fn invalid_scenarios_are_rejected() {
    for scenario in [
        ScenarioConfig { participation: 0.0, ..Default::default() },
        ScenarioConfig { participation: 1.5, ..Default::default() },
        ScenarioConfig {
            straggler: StragglerConfig { frac: 2.0, factor: 4.0 },
            ..Default::default()
        },
        ScenarioConfig { partition: Partition::Dirichlet(-0.5), ..Default::default() },
    ] {
        let mut cfg = base_cfg(SchemeKind::SflGa);
        cfg.scenario = scenario;
        assert!(Trainer::native(&manifest(), cfg).is_err());
    }
}

/// Non-IID + partial participation trains end to end for every scheme and
/// still evaluates (the whole point of the scenario engine).
#[test]
fn every_scheme_trains_under_full_scenario() {
    for scheme in [
        SchemeKind::SflGa,
        SchemeKind::SflGaDrift,
        SchemeKind::Sfl,
        SchemeKind::Psl,
        SchemeKind::Fl,
    ] {
        let mut cfg = base_cfg(scheme);
        cfg.scenario = ScenarioConfig {
            partition: Partition::Shards(2),
            participation: 0.5,
            straggler: StragglerConfig { frac: 0.25, factor: 4.0 },
        };
        let mut t = Trainer::native(&manifest(), cfg).unwrap();
        let stats = t.run(2).unwrap();
        assert_eq!(stats.len(), 2);
        let (loss, acc) = stats.last().unwrap().test.expect("final round evaluates");
        assert!(loss.is_finite() && (0.0..=1.0).contains(&acc), "{scheme:?}: {loss} {acc}");
    }
}

/// The CCC environment prices stragglers into the allocator's χ: a slow
/// cohort raises the optimal uplink-leg latency bound.
#[test]
fn ccc_env_costs_reflect_stragglers() {
    use sfl_ga::ccc::{CccConfig, Env};
    let spec = Manifest::builtin().for_dataset("mnist").unwrap().clone();
    let cfg = || CccConfig { alloc: AllocPolicy::Equal, ..Default::default() };
    let mut plain = Env::new(spec.clone(), Default::default(), Default::default(), cfg(), 4, 3);
    let scenario = ScenarioConfig {
        straggler: StragglerConfig { frac: 0.5, factor: 8.0 },
        ..Default::default()
    };
    let mut slow = Env::with_scenario(
        spec,
        Default::default(),
        Default::default(),
        cfg(),
        4,
        3,
        scenario,
    );
    // Same seed → same channel draw; only the compute profile differs.
    let (st_p, _) = plain.reset();
    let (st_s, _) = slow.reset();
    assert_eq!(st_p.gains, st_s.gains);
    for cut in 1..=4 {
        let (_, chi_p, _) = plain.cost_components(&st_p, cut);
        let (_, chi_s, _) = slow.cost_components(&st_s, cut);
        assert!(
            chi_s >= chi_p,
            "cut {cut}: straggler χ {chi_s} < homogeneous χ {chi_p}"
        );
    }
}
