//! Convergence-theory checks (Theorem 2 / Remark 1): properties of the
//! bound the paper derives, evaluated on the implemented Γ, and the
//! empirical counterpart measured on short native-backend training runs.

use sfl_ga::ccc::gamma_of_phi;
use sfl_ga::coordinator::{AllocPolicy, SchemeKind, TrainConfig, Trainer};
use sfl_ga::model::Manifest;

/// Theorem 2's bound: the cutting-point term (4/T)ΣΓ(φ_t(v)) is monotone
/// non-decreasing in v for any round sequence — smaller client models give
/// a tighter bound (Remark 1).
#[test]
fn theorem2_cut_term_monotone() {
    let manifest = Manifest::builtin();
    for key in ["28x28x1", "32x32x3"] {
        let spec = &manifest.shapes[key];
        let term = |v: usize| 4.0 * gamma_of_phi(spec, v, 10.0);
        for v in 1..4 {
            assert!(
                term(v) <= term(v + 1),
                "{key}: bound term decreased from v={v} to v={}",
                v + 1
            );
        }
    }
}

/// The bound's gradient-variance term 4Lησ²Σ(ρ^n)² is minimized by equal
/// data splits (Jensen): check Σρ² for IID vs skewed splits.
#[test]
fn variance_term_minimized_by_equal_weights() {
    let equal: f64 = (0..10).map(|_| 0.1f64 * 0.1).sum();
    let skewed: f64 = [0.5, 0.3, 0.1, 0.02, 0.02, 0.02, 0.01, 0.01, 0.01, 0.01]
        .iter()
        .map(|r| r * r)
        .sum();
    assert!(equal < skewed);
}

/// Empirical Remark 1: after the same number of rounds, smaller cuts reach
/// a train loss at least as good as the largest cut (allowing noise slack).
/// This is the mechanism behind Fig. 3.  Averaged over three seeds so a
/// single lucky/unlucky init or batch stream cannot flip the comparison —
/// the claim is about the expected curves, not one realization.
#[test]
fn empirical_smaller_cut_converges_no_worse() {
    let manifest = Manifest::builtin_with_batches(8, 32);
    const SEEDS: [u64; 3] = [11, 29, 47];
    let mean_loss_at = |cut: usize| {
        let total: f64 = SEEDS
            .iter()
            .map(|&seed| {
                let cfg = TrainConfig {
                    scheme: SchemeKind::SflGa,
                    num_clients: 3,
                    rounds: 5,
                    eval_every: 5,
                    samples_per_client: 48,
                    test_samples: 32,
                    seed,
                    alloc: AllocPolicy::Equal,
                    ..Default::default()
                };
                let mut t = Trainer::native(&manifest, cfg).unwrap();
                let stats = t.run(cut).unwrap();
                stats.last().unwrap().test.unwrap().0
            })
            .sum();
        total / SEEDS.len() as f64
    };
    let l1 = mean_loss_at(1);
    let l4 = mean_loss_at(4);
    assert!(
        l1 <= l4 * 1.10,
        "v=1 mean loss {l1} should be <= v=4 mean loss {l4} (with 10% slack, 3 seeds)"
    );
}

/// Learning-rate condition of Lemma 1: 2L²η²τ(τ-1) ≤ 1/5 holds trivially
/// for τ=1 (the default) for any η, L — the code must accept any lr there;
/// and for τ>1 the config remains constructible (the analysis bound is a
/// theory statement, not a runtime clamp — we assert the default stays
/// well inside it for a representative L).
#[test]
fn lemma1_lr_condition_default_config() {
    let cfg = TrainConfig::default();
    assert_eq!(cfg.tau, 1);
    let l_smooth = 10.0f64; // representative Lipschitz constant
    let eta = cfg.lr as f64;
    let tau = 2.0f64; // the smallest multi-epoch setting
    let lhs = 2.0 * l_smooth * l_smooth * eta * eta * tau * (tau - 1.0);
    assert!(lhs <= 0.2, "default lr {eta} violates Lemma 1 at tau=2: {lhs}");
}
