//! Registry-wide split-model contract (DESIGN.md §Model registry): for
//! EVERY architecture in the zoo, at EVERY cut of its menu, on BOTH
//! input geometries, the split path (client_fwd → server_grad →
//! client_grad) must reproduce the full-model gradient EXACTLY — the
//! two paths run the identical kernels on identical buffers, so the
//! equality is bitwise, not approximate.  This is the `builtin`
//! split-vs-full guarantee (`runtime/native` unit tests) promoted to a
//! registry invariant: adding an architecture means inheriting it.

use sfl_ga::data::{generate, init};
use sfl_ga::model::registry;
use sfl_ga::runtime::{Backend, NativeBackend, ScratchHandle, Tensor};
use sfl_ga::tensor;

/// Backend + He-init params + one deterministic batch for `(model, ds)`.
fn setup(model: &str, ds: &str) -> (NativeBackend, Vec<Vec<f32>>, Tensor, Tensor) {
    let manifest = registry::manifest_with_batches(model, 8, 32).unwrap();
    let spec = manifest.for_dataset(ds).unwrap().clone();
    let params = init::init_params(&spec, 0xC0FFEE);
    let data = generate(&spec, ds, 8, 3);
    let (x, y1h) = data.batch(&(0..8).collect::<Vec<_>>());
    (NativeBackend::new(spec).unwrap(), params, x, y1h)
}

#[test]
fn split_equals_full_bitwise_at_every_cut_of_every_arch() {
    for model in registry::MODELS {
        for ds in ["mnist", "cifar10"] {
            let (be, params, x, y1h) = setup(model, ds);
            let (loss_full, g_full) = be.full_grad(&params, &x, &y1h).unwrap();
            assert!(loss_full.is_finite(), "{model}/{ds}: full loss {loss_full}");
            for cut in be.spec().menu().ids() {
                let nc = be.spec().cut(cut).client_params;
                let smashed = be.client_fwd(cut, &params[..nc], &x).unwrap();
                let (loss_split, g_ws, g_s) =
                    be.server_grad(cut, &params[nc..], &smashed, &y1h).unwrap();
                let mut g_split = be.client_grad(cut, &params[..nc], &x, &g_s).unwrap();
                g_split.extend(g_ws);
                assert_eq!(loss_full, loss_split, "{model}/{ds} cut {cut}: loss");
                let diff = tensor::max_abs_diff(&g_split, &g_full);
                assert!(diff == 0.0, "{model}/{ds} cut {cut}: split grad differs by {diff}");
            }
        }
    }
}

#[test]
fn smashed_shapes_match_the_cut_specs() {
    for model in registry::MODELS {
        let (be, params, x, _) = setup(model, "mnist");
        for cut in be.spec().menu().ids() {
            let cs = be.spec().cut(cut).clone();
            let smashed = be.client_fwd(cut, &params[..cs.client_params], &x).unwrap();
            assert_eq!(
                smashed.shape, cs.smashed_shape,
                "{model} cut {cut}: smashed shape vs manifest"
            );
            // φ(v) really is the client-side parameter count at this cut.
            let phi: usize = be.spec().params[..cs.client_params].iter().map(|p| p.size()).sum();
            assert_eq!(phi, cs.phi, "{model} cut {cut}: phi");
        }
    }
}

/// Scratch purity extends to the transformer kernels: re-running a role
/// through a now-dirty arena (first call left layernorm stats, attention
/// probs and GELU buffers behind) must not change a bit.
#[test]
fn dirty_scratch_is_bitwise_neutral_for_the_transformer() {
    let (be, params, x, y1h) = setup("txf", "mnist");
    let handle = ScratchHandle::new();
    let (loss_a, g_a) = be.full_grad_with(&handle, &params, &x, &y1h).unwrap();
    let (loss_b, g_b) = be.full_grad_with(&handle, &params, &x, &y1h).unwrap();
    assert_eq!(loss_a, loss_b);
    assert_eq!(tensor::max_abs_diff(&g_a, &g_b), 0.0);
    for cut in be.spec().menu().ids() {
        let nc = be.spec().cut(cut).client_params;
        let s_plain = be.client_fwd(cut, &params[..nc], &x).unwrap();
        let s_dirty = be.client_fwd_with(&handle, cut, &params[..nc], &x).unwrap();
        assert_eq!(s_plain, s_dirty, "cut {cut}: client_fwd under a dirty arena");
    }
}

/// One SGD step on He-init params must move the loss for every arch —
/// catches degenerate wiring (e.g. zero-init layernorm gains) that the
/// exact-equality tests above cannot see.
#[test]
fn every_arch_produces_live_gradients() {
    for model in registry::MODELS {
        let (be, params, x, y1h) = setup(model, "mnist");
        let (loss0, grads) = be.full_grad(&params, &x, &y1h).unwrap();
        let touched = grads.iter().filter(|g| g.iter().any(|&v| v != 0.0)).count();
        assert_eq!(touched, grads.len(), "{model}: some parameter array got a zero gradient");
        let stepped: Vec<Vec<f32>> = params
            .iter()
            .zip(&grads)
            .map(|(p, g)| p.iter().zip(g).map(|(&pv, &gv)| pv - 0.02 * gv).collect())
            .collect();
        let (loss1, _) = be.full_grad(&stepped, &x, &y1h).unwrap();
        assert!(loss1 < loss0, "{model}: SGD step did not reduce loss ({loss0} -> {loss1})");
    }
}
