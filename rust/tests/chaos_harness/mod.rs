//! Chaos-testing harness for the networked runtime (`tests/chaos.rs`,
//! `tests/net_equivalence.rs`): spawn real `sfl-participant` processes,
//! inject faults against them — Pause (SIGSTOP), Delay, Kill, PacketLoss
//! — and keep CI safe with kill-on-drop guards plus an in-test watchdog.
//!
//! Everything here is test scaffolding: deliberately small, synchronous
//! and dependency-free.

// Shared by several test crates; each uses a different subset.
#![allow(dead_code)]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A spawned process that is ALWAYS killed (and reaped) on drop, so a
/// failing test never leaks a participant into the CI runner.  Stdout is
/// piped through a reader thread; [`ProcGuard::wait_for_line`] observes
/// it with a timeout.
pub struct ProcGuard {
    pub name: String,
    child: Child,
    lines: Receiver<String>,
}

impl ProcGuard {
    pub fn spawn(name: &str, cmd: &mut Command) -> ProcGuard {
        let mut child = cmd
            .stdout(Stdio::piped())
            .spawn()
            .unwrap_or_else(|e| panic!("spawning {name}: {e}"));
        let stdout = child.stdout.take().expect("stdout piped");
        let (tx, lines) = mpsc::channel();
        let thread_name = name.to_string();
        std::thread::spawn(move || {
            for line in BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                eprintln!("[{thread_name} stdout] {line}");
                if tx.send(line).is_err() {
                    break;
                }
            }
        });
        ProcGuard { name: name.to_string(), child, lines }
    }

    pub fn pid(&self) -> u32 {
        self.child.id()
    }

    /// Await a stdout line starting with `prefix`; panics at `timeout`
    /// (the watchdog's job is the harder hang case).
    pub fn wait_for_line(&self, prefix: &str, timeout: Duration) -> String {
        let t_end = Instant::now() + timeout;
        loop {
            let left = t_end.saturating_duration_since(Instant::now());
            if left.is_zero() {
                panic!("{}: no '{prefix}' line within {timeout:?}", self.name);
            }
            match self.lines.recv_timeout(left) {
                Ok(line) if line.starts_with(prefix) => return line,
                Ok(_) => continue,
                Err(_) => panic!("{}: stdout closed before '{prefix}'", self.name),
            }
        }
    }

    /// Chaos: SIGKILL, immediately.
    pub fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    /// Chaos: freeze the process (SIGSTOP) — an extreme straggler.
    #[cfg(unix)]
    pub fn pause(&self) {
        signal(self.pid(), "STOP");
    }

    /// Undo [`ProcGuard::pause`] (SIGCONT).
    #[cfg(unix)]
    pub fn resume(&self) {
        signal(self.pid(), "CONT");
    }

    /// Wait for a clean exit, asserting the status.
    pub fn wait_success(&mut self, timeout: Duration) {
        let t_end = Instant::now() + timeout;
        loop {
            match self.child.try_wait().expect("try_wait") {
                Some(status) => {
                    assert!(status.success(), "{} exited with {status}", self.name);
                    return;
                }
                None if Instant::now() >= t_end => {
                    panic!("{} still running after {timeout:?}", self.name)
                }
                None => std::thread::sleep(Duration::from_millis(20)),
            }
        }
    }
}

impl Drop for ProcGuard {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Send a named signal to a pid via kill(1) — the raw form of
/// [`ProcGuard::pause`]/[`ProcGuard::resume`] for injection threads that
/// only hold a pid.
#[cfg(unix)]
pub fn signal(pid: u32, sig: &str) {
    let status = Command::new("kill")
        .arg(format!("-{sig}"))
        .arg(pid.to_string())
        .status()
        .expect("spawn kill(1)");
    assert!(status.success(), "kill -{sig} {pid} failed");
}

/// Spawn one `sfl-participant` binary joined to `addr` as `id`.
pub fn spawn_participant(addr: &str, id: u64) -> ProcGuard {
    spawn_participant_with(addr, id, &[])
}

/// [`spawn_participant`] with extra CLI flags (`--reconnect` windows and
/// friends for the churn scenarios).
pub fn spawn_participant_with(addr: &str, id: u64, extra: &[&str]) -> ProcGuard {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sfl-participant"));
    cmd.arg("--connect")
        .arg(addr)
        .arg("--client-id")
        .arg(id.to_string())
        // Belt and suspenders: even an orphaned participant exits on its
        // own well before a CI-lane timeout.
        .arg("--idle-timeout-ms")
        .arg("120000");
    for flag in extra {
        cmd.arg(flag);
    }
    ProcGuard::spawn(&format!("participant-{id}"), &mut cmd)
}

// ----------------------------------------------------------- packet loss

/// A frame-aware TCP relay for packet-loss injection: forwards whole
/// protocol frames between a participant and the coordinator, and after
/// `allow_upstream` client→coordinator frames silently discards the rest
/// (the connection stays open — a black hole, not a reset).  Downstream
/// keeps flowing, so the participant keeps computing; its results just
/// never arrive, exactly the loss mode the deadline policy must catch.
pub struct ChaosProxy {
    /// Address participants should connect to.
    pub addr: String,
}

impl ChaosProxy {
    pub fn start(upstream: String, allow_upstream: usize) -> ChaosProxy {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind proxy");
        let addr = listener.local_addr().expect("proxy addr").to_string();
        std::thread::spawn(move || {
            // One participant per proxy instance.
            let Ok((client, _)) = listener.accept() else { return };
            let Ok(server) = TcpStream::connect(&upstream) else { return };
            let up = (
                client.try_clone().expect("clone"),
                server.try_clone().expect("clone"),
            );
            let down = (server, client);
            std::thread::spawn(move || relay(up.0, up.1, Some(allow_upstream)));
            relay(down.0, down.1, None);
        });
        ChaosProxy { addr }
    }
}

/// Pump frames `src` → `dst`; with `allow = Some(n)` discard every frame
/// after the first `n`.  Uses the same length-prefix grammar as
/// `protocol::wire` (4-byte LE length + payload).
fn relay(mut src: TcpStream, mut dst: TcpStream, allow: Option<usize>) {
    let mut forwarded = 0usize;
    loop {
        let mut len = [0u8; 4];
        if src.read_exact(&mut len).is_err() {
            return;
        }
        let n = u32::from_le_bytes(len) as usize;
        let mut payload = vec![0u8; n];
        if src.read_exact(&mut payload).is_err() {
            return;
        }
        if let Some(cap) = allow {
            if forwarded >= cap {
                continue; // black hole
            }
        }
        forwarded += 1;
        if dst.write_all(&len).is_err() || dst.write_all(&payload).is_err() {
            return;
        }
        let _ = dst.flush();
    }
}

// -------------------------------------------------------------- watchdog

/// Hard in-test hang guard: aborts the whole test process if not
/// disarmed (dropped) within the budget.  The CI lane's `timeout` is the
/// outer net; this one produces a named, per-test failure point.
pub struct Watchdog {
    disarmed: Arc<AtomicBool>,
}

impl Watchdog {
    pub fn arm(name: &'static str, budget: Duration) -> Watchdog {
        let disarmed = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&disarmed);
        std::thread::spawn(move || {
            let t_end = Instant::now() + budget;
            while Instant::now() < t_end {
                if flag.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(100));
            }
            if !flag.load(Ordering::Relaxed) {
                eprintln!("WATCHDOG: '{name}' exceeded {budget:?}; aborting");
                std::process::abort();
            }
        });
        Watchdog { disarmed }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.disarmed.store(true, Ordering::Relaxed);
    }
}
