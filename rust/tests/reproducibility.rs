//! Reproducibility contracts fixed by the bugfix sweep (see CHANGES.md):
//!
//! 1. `Trainer::reset(seed)` + `run` is BITWISE a freshly constructed
//!    `Trainer` with that seed — every seed-dependent stream (datasets,
//!    partition/ρ, batcher order, capacity table, channel fading,
//!    participation draws, model init) is re-derived on reset.
//! 2. `ccc::Env::reset` re-derives the participation stream, so every
//!    episode replays the same cohort sequence (the channel deliberately
//!    keeps fading across episodes).
//! 3. FL reports the τ-averaged train loss, like the split schemes — at
//!    τ > 1 the fig-3-style loss curves compare like quantities.
//! 4. Env and Trainer share one channel-seed convention: for equal run
//!    seeds they draw identical gain trajectories.
//! 5. `Trainer::run`'s deferred (pipelined) evaluation is bitwise the
//!    synchronous `run_round` evaluation.

use sfl_ga::ccc::{CccConfig, Env};
use sfl_ga::coordinator::{AllocPolicy, SchemeKind, TrainConfig, Trainer};
use sfl_ga::data::partition::Partition;
use sfl_ga::latency::ComputeConfig;
use sfl_ga::model::Manifest;
use sfl_ga::scenario::{ScenarioConfig, StragglerConfig};
use sfl_ga::wireless::NetConfig;

/// A small config exercising EVERY seeded stream: Dirichlet partition,
/// partial participation, stragglers, eval tail batch.
fn scenario_cfg(seed: u64, scheme: SchemeKind) -> TrainConfig {
    TrainConfig {
        scheme,
        num_clients: 4,
        rounds: 3,
        eval_every: 2,
        samples_per_client: 16,
        test_samples: 40,
        seed,
        threads: 1,
        alloc: AllocPolicy::Equal,
        scenario: ScenarioConfig {
            partition: Partition::Dirichlet(0.3),
            participation: 0.5,
            straggler: StragglerConfig { frac: 0.25, factor: 4.0 },
        },
        ..Default::default()
    }
}

/// Everything a run observes, as raw bits.
fn run_fingerprint(t: &mut Trainer, cut: usize) -> (Vec<u64>, Vec<u32>) {
    let mut stat_bits = Vec::new();
    for s in t.run(cut).unwrap() {
        stat_bits.push(s.train_loss.to_bits());
        stat_bits.push(s.comm.total_bits().to_bits());
        stat_bits.push(s.latency.total().to_bits());
        if let Some((tl, ta)) = s.test {
            stat_bits.push(tl.to_bits());
            stat_bits.push(ta.to_bits());
        }
    }
    let param_bits = t.global_params(cut).iter().flatten().map(|v| v.to_bits()).collect();
    (stat_bits, param_bits)
}

#[test]
fn reset_then_run_is_bitwise_a_fresh_trainer() {
    let manifest = Manifest::builtin_with_batches(8, 32);
    for scheme in [SchemeKind::SflGa, SchemeKind::Fl] {
        // Train under seed 5, then reset to seed 9: datasets, shards,
        // batcher streams, caps, channel and participation draws must all
        // re-derive from 9 — not stay mid-stream from the seed-5 run.
        let mut reused = Trainer::native(&manifest, scenario_cfg(5, scheme)).unwrap();
        reused.run(2).unwrap();
        reused.reset(9);
        let a = run_fingerprint(&mut reused, 2);
        let mut fresh = Trainer::native(&manifest, scenario_cfg(9, scheme)).unwrap();
        let b = run_fingerprint(&mut fresh, 2);
        assert_eq!(a.0, b.0, "{scheme:?}: reset trainer's stats diverge from a fresh trainer");
        assert_eq!(a.1, b.1, "{scheme:?}: reset trainer's params diverge from a fresh trainer");
    }
}

#[test]
fn resetting_to_the_same_seed_replays_the_run_bitwise() {
    let manifest = Manifest::builtin_with_batches(8, 32);
    let mut t = Trainer::native(&manifest, scenario_cfg(7, SchemeKind::SflGa)).unwrap();
    let first = run_fingerprint(&mut t, 2);
    t.reset(7);
    let second = run_fingerprint(&mut t, 2);
    assert_eq!(first, second, "reset(seed) must rewind every seeded stream");
}

fn small_env(seed: u64, participation: f64) -> Env {
    let manifest = Manifest::builtin();
    let spec = manifest.for_dataset("mnist").unwrap().clone();
    let cfg = CccConfig {
        episodes: 2,
        steps_per_episode: 6,
        alloc: AllocPolicy::Equal,
        ..Default::default()
    };
    Env::with_scenario(
        spec,
        NetConfig::default(),
        ComputeConfig::default(),
        cfg,
        6,
        seed,
        ScenarioConfig {
            participation,
            straggler: StragglerConfig { frac: 0.25, factor: 4.0 },
            ..Default::default()
        },
    )
}

#[test]
fn env_episodes_replay_the_same_cohort_sequence() {
    let mut env = small_env(11, 0.5);
    let mut episode_cohorts = Vec::new();
    let mut episode_gains = Vec::new();
    for _ in 0..2 {
        let (mut state, _) = env.reset();
        episode_gains.push(state.gains.clone());
        let mut cohorts = Vec::new();
        for _ in 0..6 {
            let out = env.step(&state, 2);
            let cohort = out.cohort.expect("partial participation draws a cohort");
            assert_eq!(out.participants, cohort.len());
            cohorts.push(cohort);
            state = out.next_state;
        }
        episode_cohorts.push(cohorts);
    }
    // Episode 2's cohort sequence is episode 1's, step for step — the
    // participation stream re-derives from the run seed on reset.
    assert_eq!(
        episode_cohorts[0], episode_cohorts[1],
        "episode cohorts depend on how many episodes ran before"
    );
    // The sequence actually varies within an episode (the draw is live).
    assert!(
        episode_cohorts[0].iter().any(|c| c != &episode_cohorts[0][0]),
        "cohort sequence is degenerate: {:?}",
        episode_cohorts[0]
    );
    // The channel deliberately keeps fading ACROSS episodes (block-fading
    // continuity): episode starts see fresh gain realizations.
    assert_ne!(
        episode_gains[0], episode_gains[1],
        "channel was reset too — episodes should explore fresh fading"
    );
}

#[test]
fn env_and_trainer_draw_identical_gain_trajectories() {
    let seed = 21;
    let clients = 5;
    let manifest = Manifest::builtin_with_batches(8, 32);
    let cfg = TrainConfig {
        num_clients: clients,
        rounds: 2,
        samples_per_client: 16,
        test_samples: 32,
        seed,
        threads: 1,
        ..Default::default()
    };
    let mut trainer = Trainer::native(&manifest, cfg).unwrap();
    let spec = manifest.for_dataset("mnist").unwrap().clone();
    let ccc = CccConfig { alloc: AllocPolicy::Equal, ..Default::default() };
    let mut env =
        Env::new(spec, NetConfig::default(), ComputeConfig::default(), ccc, clients, seed);
    // Draw 4 successive rounds from each; the gain sequences must agree
    // bitwise — the optimizer prices the hardware the simulator runs on.
    let (mut state, _) = env.reset();
    for round in 0..4 {
        let trainer_gains: Vec<u64> =
            trainer.draw_channel().gains.iter().map(|g| g.to_bits()).collect();
        let env_gains: Vec<u64> = state.gains.iter().map(|g| g.to_bits()).collect();
        assert_eq!(trainer_gains, env_gains, "gain trajectories diverge at round {round}");
        state = env.step(&state, 2).next_state;
    }
}

/// With lr = 0 the model never moves, so per-epoch losses depend only on
/// the (deterministic) batch stream: one τ=2 round must report the mean
/// of the two corresponding τ=1 rounds' losses — for FL exactly like the
/// split schemes (FL used to report only the FIRST local epoch's loss).
/// Two equal-sized clients keep FL's ρ-weighted model aggregation exact
/// (0.5·w + 0.5·w ≡ w bitwise), so the τ=1 run's second round sees the
/// same model the τ=2 run's second epoch does.
#[test]
fn train_loss_is_tau_averaged_for_fl_and_split_alike() {
    let manifest = Manifest::builtin_with_batches(8, 32);
    for scheme in [SchemeKind::Fl, SchemeKind::SflGa] {
        let base = TrainConfig {
            scheme,
            num_clients: 2,
            lr: 0.0,
            samples_per_client: 16,
            test_samples: 32,
            seed: 31,
            threads: 1,
            eval_every: usize::MAX - 1,
            alloc: AllocPolicy::Equal,
            ..Default::default()
        };
        let mut two_epochs =
            Trainer::native(&manifest, TrainConfig { rounds: 1, tau: 2, ..base.clone() })
                .unwrap();
        let avg = two_epochs.run(2).unwrap()[0].train_loss;
        let mut per_round =
            Trainer::native(&manifest, TrainConfig { rounds: 2, tau: 1, ..base }).unwrap();
        let stats = per_round.run(2).unwrap();
        let want = (stats[0].train_loss + stats[1].train_loss) / 2.0;
        assert!(
            (avg - want).abs() < 1e-9,
            "{scheme:?}: tau=2 loss {avg} != mean of per-epoch losses {want}"
        );
        assert_ne!(
            avg.to_bits(),
            stats[0].train_loss.to_bits(),
            "{scheme:?}: tau=2 loss equals the first epoch's loss exactly — not averaged?"
        );
    }
}

/// `Trainer::run` overlaps round t's eval with round t+1's fan-out; the
/// attached values must be bitwise what the synchronous `run_round` path
/// computes.
#[test]
fn deferred_eval_matches_synchronous_eval_bitwise() {
    let manifest = Manifest::builtin_with_batches(8, 32);
    for threads in [1usize, 4] {
        let mk = || {
            let cfg = TrainConfig { threads, ..scenario_cfg(13, SchemeKind::SflGa) };
            Trainer::native(&manifest, cfg).unwrap()
        };
        let mut overlapped = mk();
        let via_run = overlapped.run(2).unwrap();
        let mut synchronous = mk();
        let mut via_rounds = Vec::new();
        for _ in 0..3 {
            let state = synchronous.draw_channel();
            via_rounds.push(synchronous.run_round(2, &state).unwrap());
        }
        assert_eq!(via_run.len(), via_rounds.len());
        for (a, b) in via_run.iter().zip(&via_rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(
                a.test.map(|(l, c)| (l.to_bits(), c.to_bits())),
                b.test.map(|(l, c)| (l.to_bits(), c.to_bits())),
                "deferred eval diverges from synchronous eval at round {} (threads {threads})",
                a.round
            );
        }
    }
}
