//! Allocator benchmarks: P2.1 solve cost vs client count and channel
//! conditions.  The solver sits on Algorithm 1's inner loop (one solve per
//! DDQN exploration step) AND on every optimally-allocated round, so its
//! latency budget is < ~10 ms for N=10 (DESIGN.md §Perf).

use sfl_ga::allocator::RoundProblem;
use sfl_ga::benchlib::{self, bench};
use sfl_ga::util::rng::Pcg;
use sfl_ga::wireless::{avg_gain, dbm_to_watt};

fn problem(n: usize, seed: u64) -> RoundProblem {
    let mut rng = Pcg::new(seed, 0xBE7C);
    RoundProblem {
        x_up_bits: 3.2e6,
        x_down_bits: 3.2e6,
        gains: (0..n)
            .map(|_| avg_gain(rng.range(0.05, 0.5)) * rng.exponential(1.0).max(0.05))
            .collect(),
        a: vec![1.8; n],
        d: vec![3.6; n],
        c: (0..n).map(|_| rng.range(1e9, 6e9)).collect(),
        b_total: 20e6,
        f_total: 100e9,
        p_max: dbm_to_watt(25.0),
        p_server: dbm_to_watt(33.0),
        n0: dbm_to_watt(-174.0),
    }
}

fn main() {
    println!("== allocator (P2.1) ==");
    for n in [2, 5, 10, 20, 50] {
        let p = problem(n, n as u64);
        bench(&format!("solve_optimal/N={n}"), 3, benchlib::iters(20, 3), || p.solve().chi);
    }
    let p = problem(10, 99);
    bench("solve_equal/N=10", 10, benchlib::iters(200, 20), || p.solve_equal().chi);
    bench("psi_star/N=10", 10, benchlib::iters(500, 50), || p.psi_star());
}
