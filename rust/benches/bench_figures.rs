//! Figure-harness benchmarks: the timing-model sweeps behind Fig. 8 and
//! the per-round cost model of Figs. 5/6 (no NN training — these isolate
//! the simulation/optimization layers that every figure run multiplies).

use sfl_ga::benchlib::{self, bench};
use sfl_ga::coordinator::SchemeKind;
use sfl_ga::coordinator::timing::{AllocPolicy, round_latency};
use sfl_ga::latency::ComputeConfig;
use sfl_ga::model::Manifest;
use sfl_ga::wireless::{Channel, NetConfig};

fn main() -> anyhow::Result<()> {
    println!("== figure timing models ==");
    let manifest = Manifest::builtin();
    let spec = manifest.for_dataset("mnist")?.clone();
    let net = NetConfig::default();
    let comp = ComputeConfig::default();
    let mut ch = Channel::new(net.clone(), 10, 11);
    let st = ch.draw_round();

    for scheme in SchemeKind::all() {
        bench(&format!("round_latency_opt/{}", scheme.name()), 2, benchlib::iters(30, 5), || {
            round_latency(scheme, &spec, spec.cut(2), &net, &comp, &st, AllocPolicy::Optimal, 1)
                .total()
        });
    }
    bench("round_latency_equal/sfl-ga", 10, benchlib::iters(200, 20), || {
        let pol = AllocPolicy::Equal;
        round_latency(SchemeKind::SflGa, &spec, spec.cut(2), &net, &comp, &st, pol, 1).total()
    });
    // Fig. 8's full sweep: 6 bandwidths x 4 schemes x K draws.
    bench("fig8_sweep(6bw x 4schemes x 5draws)", 1, benchlib::iters(5, 1), || {
        let mut total = 0.0;
        for bw in [5.0, 10.0, 15.0, 20.0, 25.0, 30.0] {
            let net = NetConfig { bandwidth: bw * 1e6, ..Default::default() };
            let mut ch = Channel::new(net.clone(), 10, bw as u64);
            for _ in 0..5 {
                let st = ch.draw_round();
                for scheme in SchemeKind::all() {
                    total += round_latency(
                        scheme,
                        &spec,
                        spec.cut(2),
                        &net,
                        &comp,
                        &st,
                        AllocPolicy::Optimal,
                        1,
                    )
                    .total();
                }
            }
        }
        total
    });
    Ok(())
}
