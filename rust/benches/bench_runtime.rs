//! Runtime benchmarks: native-backend execution of every model role — the
//! L3↔L2 boundary cost that bounds the real (non-simulated) round time.
//! Runs from a clean checkout (no artifacts required).

use sfl_ga::benchlib::{self, bench};
use sfl_ga::data::init::init_params;
use sfl_ga::data::{Batcher, generate, partition};
use sfl_ga::model::Manifest;
use sfl_ga::runtime::ModelRuntime;

fn main() -> anyhow::Result<()> {
    println!("== runtime (native backend) ==");
    // Quick mode (CI bench-smoke): test-sized batches, fewer iterations.
    let manifest = if benchlib::quick() {
        Manifest::builtin_with_batches(8, 32)
    } else {
        Manifest::builtin()
    };
    let iters = benchlib::iters(10, 2);
    let rt = ModelRuntime::native(&manifest, "mnist")?;
    let spec = rt.spec().clone();

    let params = init_params(&spec, 7);
    let ds = generate(&spec, "mnist", 256, 5);
    let shard = partition(&ds, 1, None, 1).remove(0);
    let mut batcher = Batcher::new(shard, spec.train_batch, 3);
    let (x, y) = ds.batch(&batcher.next_batch());

    for cut in [1usize, 2, 4] {
        let nc = spec.cut(cut).client_params;
        let wc = params[..nc].to_vec();
        let ws = params[nc..].to_vec();
        let smashed = rt.client_fwd(cut, &wc, &x)?;
        bench(&format!("client_fwd/v{cut}"), 2, iters, || {
            rt.client_fwd(cut, &wc, &x).unwrap()
        });
        bench(&format!("server_grad/v{cut}"), 2, iters, || {
            rt.server_grad(cut, &ws, &smashed, &y).unwrap()
        });
        let (_, _, gs) = rt.server_grad(cut, &ws, &smashed, &y)?;
        bench(&format!("client_grad/v{cut}"), 2, iters, || {
            rt.client_grad(cut, &wc, &x, &gs).unwrap()
        });
    }
    bench("full_grad", 2, iters, || rt.full_grad(&params, &x, &y).unwrap());

    let eval_idx: Vec<usize> = (0..spec.eval_batch.min(ds.len())).collect();
    let (ex, ey) = ds.batch(&eval_idx);
    bench(&format!("eval(batch={})", ex.shape[0]), 1, benchlib::iters(5, 2), || {
        rt.eval(&params, &ex, &ey).unwrap()
    });
    Ok(())
}
