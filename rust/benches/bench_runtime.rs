//! Runtime benchmarks: PJRT execute round-trips for every artifact role —
//! the L3↔L2 boundary cost that bounds the real (non-simulated) round
//! time.  Requires `make artifacts`.

use sfl_ga::benchlib::{bench, bench_once};
use sfl_ga::data::init::init_params;
use sfl_ga::data::{generate, partition, Batcher};
use sfl_ga::model::Manifest;
use sfl_ga::runtime::{ModelRuntime, Tensor};

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping bench_runtime: run `make artifacts` first");
        return Ok(());
    }
    println!("== runtime (PJRT engine) ==");
    let manifest = Manifest::load(dir)?;
    let rt_handle = bench_once("load+compile 14 artifacts (mnist)", || {
        ModelRuntime::load(dir, &manifest, "mnist").unwrap()
    });
    let _ = rt_handle;
    let rt = ModelRuntime::load(dir, &manifest, "mnist")?;
    let spec = rt.spec().clone();

    let params = init_params(&spec, 7);
    let ds = generate(&spec, "mnist", 256, 5);
    let shard = partition(&ds, 1, None, 1).remove(0);
    let mut batcher = Batcher::new(shard, spec.train_batch, 3);
    let (x, y) = ds.batch(&batcher.next_batch());

    for cut in [1usize, 2, 4] {
        let nc = spec.cut(cut).client_params;
        let wc = params[..nc].to_vec();
        let ws = params[nc..].to_vec();
        let smashed = rt.client_fwd(cut, &wc, &x)?;
        bench(&format!("client_fwd/v{cut}"), 3, 20, || {
            rt.client_fwd(cut, &wc, &x).unwrap()
        });
        bench(&format!("server_grad/v{cut}"), 3, 20, || {
            rt.server_grad(cut, &ws, &smashed, &y).unwrap()
        });
        let (_, _, gs) = rt.server_grad(cut, &ws, &smashed, &y)?;
        bench(&format!("client_grad/v{cut}"), 3, 20, || {
            rt.client_grad(cut, &wc, &x, &gs).unwrap()
        });
    }
    bench("full_grad", 3, 20, || rt.full_grad(&params, &x, &y).unwrap());

    let eval_idx: Vec<usize> = (0..spec.eval_batch.min(ds.len())).collect();
    let (ex, ey) = ds.batch(&eval_idx);
    if ex.shape[0] == spec.eval_batch {
        bench("eval(batch=256)", 3, 20, || rt.eval(&params, &ex, &ey).unwrap());
    }

    // Engine channel overhead: a no-compute round-trip approximation using
    // the tiniest executable (v4 client_fwd on zero input is the smallest).
    let zeros = Tensor::zeros(&[spec.train_batch, 28, 28, 1]);
    let wc4 = params[..spec.cut(4).client_params].to_vec();
    bench("engine_roundtrip(v4 client_fwd)", 3, 30, || {
        rt.client_fwd(4, &wc4, &zeros).unwrap()
    });
    Ok(())
}
