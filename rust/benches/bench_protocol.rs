//! Wire-protocol micro-benchmarks: encode/decode wall-clock and
//! throughput for the messages that dominate a networked round —
//! `FwdReq` (client-side weights down), `FwdOk` (smashed batch up),
//! `BwdReq` (cotangent down) and `FullReq` (a whole FL model) — plus
//! length-prefixed frame I/O through an in-memory stream.
//!
//! The protocol is the per-round overhead the TCP transport adds over
//! loopback, so these numbers bound the coordinator-side serialization
//! cost of DESIGN.md §Transport's byte-identical encoding.  Emits
//! `BENCH_protocol.json` (override with `SFLGA_BENCH_OUT`).

use std::collections::BTreeMap;

use sfl_ga::benchlib::{self, bench};
use sfl_ga::data::init::init_params;
use sfl_ga::model::Manifest;
use sfl_ga::protocol::wire::{read_frame, write_frame};
use sfl_ga::protocol::Msg;
use sfl_ga::runtime::Tensor;
use sfl_ga::util::json::Json;

/// Deterministic dense values in [-0.5, 0.5).
fn gen_vec(offset: u64, n: usize) -> Vec<f32> {
    (0..n as u64)
        .map(|j| {
            let h = ((offset + j) as u32).wrapping_mul(2654435761);
            ((h >> 16) & 0xFF) as f32 / 256.0 - 0.5
        })
        .collect()
}

struct MsgRow {
    name: &'static str,
    bytes: usize,
    encode_ns: f64,
    decode_ns: f64,
}

impl MsgRow {
    fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bytes".to_string(), Json::Num(self.bytes as f64));
        m.insert("encode_p50_ns".to_string(), Json::Num(self.encode_ns));
        m.insert("decode_p50_ns".to_string(), Json::Num(self.decode_ns));
        m.insert("encode_gb_s".to_string(), Json::Num(self.bytes as f64 / self.encode_ns));
        m.insert("decode_gb_s".to_string(), Json::Num(self.bytes as f64 / self.decode_ns));
        Json::Obj(m)
    }
}

fn measure(name: &'static str, msg: &Msg, warmup: usize, iters: usize) -> MsgRow {
    let bytes = msg.encode();
    let decoded = Msg::decode(&bytes).expect("bench message decodes");
    assert!(decoded.encode() == bytes, "{name}: roundtrip drifted");
    let enc = bench(&format!("encode {name} ({} KiB)", bytes.len() >> 10), warmup, iters, || {
        msg.encode()
    });
    let dec = bench(&format!("decode {name} ({} KiB)", bytes.len() >> 10), warmup, iters, || {
        Msg::decode(&bytes).expect("decodes")
    });
    MsgRow { name, bytes: bytes.len(), encode_ns: enc.p50_ns, decode_ns: dec.p50_ns }
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::builtin();
    let spec = manifest.for_dataset("mnist")?.clone();
    let cut = spec.cuts[spec.cuts.len() / 2].cut;
    let nc = spec.cut(cut).client_params;
    let w = init_params(&spec, 0x1417);
    let batch = spec.train_batch;
    let smashed_n = batch * spec.cut(cut).smashed_per_sample();
    let warmup = benchlib::iters(10, 2);
    let iters = benchlib::iters(200, 10);
    println!("== protocol encode/decode (mnist, cut v={cut}, batch {batch}) ==");

    let rows = vec![
        measure(
            "fwd-req",
            &Msg::FwdReq { seq: 1, cut: cut as u32, step: 0, wc: w[..nc].to_vec() },
            warmup,
            iters,
        ),
        measure(
            "fwd-ok",
            &Msg::FwdOk {
                seq: 1,
                smashed: Tensor::new(gen_vec(1, smashed_n), vec![batch, smashed_n / batch]),
                labels: Tensor::new(gen_vec(2, batch * 10), vec![batch, 10]),
            },
            warmup,
            iters,
        ),
        measure(
            "bwd-req",
            &Msg::BwdReq {
                seq: 1,
                cotangent: Tensor::new(gen_vec(3, smashed_n), vec![batch, smashed_n / batch]),
            },
            warmup,
            iters,
        ),
        measure(
            "full-req",
            &Msg::FullReq { seq: 1, step0: 0, tau: 1, lr: 0.02, w: w.clone() },
            warmup,
            iters,
        ),
    ];

    // Frame I/O over an in-memory stream: one round's four phases for one
    // participant, written and read back.
    let frame_msgs: Vec<Vec<u8>> = (0..4)
        .map(|_| Msg::FwdReq { seq: 1, cut: cut as u32, step: 0, wc: w[..nc].to_vec() }.encode())
        .collect();
    let frames = bench("frame write+read x4", warmup, iters, || {
        let mut buf = Vec::with_capacity(frame_msgs.iter().map(|m| m.len() + 4).sum());
        for m in &frame_msgs {
            write_frame(&mut buf, m).expect("write");
        }
        let mut cur = std::io::Cursor::new(buf);
        let mut n = 0usize;
        while let Some(payload) = read_frame(&mut cur).expect("read") {
            n += payload.len();
        }
        n
    });

    let mut msgs = BTreeMap::new();
    for row in &rows {
        msgs.insert(row.name.to_string(), row.json());
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("protocol".to_string()));
    root.insert("quick".to_string(), Json::Bool(benchlib::quick()));
    root.insert("cut".to_string(), Json::Num(cut as f64));
    root.insert("messages".to_string(), Json::Obj(msgs));
    root.insert("frame_io_p50_ns".to_string(), Json::Num(frames.p50_ns));
    let out = std::env::var("SFLGA_BENCH_OUT").unwrap_or_else(|_| "BENCH_protocol.json".into());
    std::fs::write(&out, Json::Obj(root).to_string() + "\n")?;
    println!("summary written to {out}");
    Ok(())
}
