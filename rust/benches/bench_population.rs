//! Virtual-population scale benchmark: drives a MILLION-client federation
//! at participation 1e-4 through real training rounds (native backend,
//! test-sized batches) and reports the peak resident population state —
//! the O(cohort) bound DESIGN.md §Population promises.  The bound is
//! *asserted*, not just reported: a 10⁶-client run at cohort K must peak
//! at exactly the bytes a 10⁴-client run at the same K peaks at, or the
//! process exits non-zero and CI's bench-smoke lane fails.
//!
//! Also times the pure population derivations (cohort enumeration,
//! per-client capacity/gain lookups) at N = 10⁶ — these are the per-round
//! coordinator overhead that must stay independent of N.
//!
//! Emits a machine-readable summary to `BENCH_population.json` (override
//! the path with `SFLGA_BENCH_OUT`, same convention as `bench_parallel`).

use std::collections::BTreeMap;
use std::time::Instant;

use sfl_ga::benchlib::{self, bench};
use sfl_ga::coordinator::{AllocPolicy, SchemeKind, TrainConfig, Trainer};
use sfl_ga::data::partition::Partition;
use sfl_ga::model::Manifest;
use sfl_ga::scenario::{ScenarioConfig, StragglerConfig};
use sfl_ga::util::json::Json;

/// One measured configuration: N clients at the given participation.
struct RunRow {
    n: usize,
    participation: f64,
    k: usize,
    rounds: usize,
    wall_ns: f64,
    peak_resident_bytes: usize,
    final_loss: f64,
}

impl RunRow {
    fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("num_clients".into(), Json::Num(self.n as f64));
        m.insert("participation".into(), Json::Num(self.participation));
        m.insert("cohort".into(), Json::Num(self.k as f64));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("wall_ns".into(), Json::Num(self.wall_ns));
        m.insert(
            "peak_resident_bytes".into(),
            Json::Num(self.peak_resident_bytes as f64),
        );
        m.insert("final_train_loss".into(), Json::Num(self.final_loss));
        Json::Obj(m)
    }
}

fn run_config(manifest: &Manifest, n: usize, participation: f64, rounds: usize) -> RunRow {
    let cfg = TrainConfig {
        scheme: SchemeKind::SflGa,
        num_clients: n,
        rounds,
        eval_every: rounds,
        samples_per_client: 16,
        test_samples: 32,
        seed: 29,
        alloc: AllocPolicy::Equal,
        scenario: ScenarioConfig {
            partition: Partition::Dirichlet(0.3),
            participation,
            straggler: StragglerConfig { frac: 0.1, factor: 4.0 },
        },
        ..Default::default()
    };
    let mut t = Trainer::native(manifest, cfg).expect("population config");
    let t0 = Instant::now();
    let stats = t.run(2).expect("training run");
    let wall_ns = t0.elapsed().as_nanos() as f64;
    let k = stats[0].participants;
    assert!(stats.iter().all(|s| s.participants == k));
    let row = RunRow {
        n,
        participation,
        k,
        rounds,
        wall_ns,
        peak_resident_bytes: t.peak_resident_population_bytes(),
        final_loss: stats.last().unwrap().train_loss,
    };
    println!(
        "population N={:>9}  r={:<7}  K={:>4}  rounds={}  wall {:>12}  peak resident {:>9} B",
        row.n,
        row.participation,
        row.k,
        row.rounds,
        sfl_ga::benchlib::fmt_ns(row.wall_ns),
        row.peak_resident_bytes,
    );
    row
}

fn main() -> anyhow::Result<()> {
    // Test-sized batches: this measures population machinery and O(cohort)
    // residency, not conv kernels (bench_kernels owns those numbers).
    let manifest = Manifest::builtin_with_batches(8, 32);
    let rounds = benchlib::iters(5, 2);
    println!("== virtual population: million-client federation ==");

    // The headline config the ISSUE pins: N = 10⁶ at participation 1e-4
    // (cohort of 100)…
    let million = run_config(&manifest, 1_000_000, 1e-4, rounds);
    // …the same cohort from a 100× smaller population — the peak resident
    // bytes must MATCH (O(cohort), zero N-dependence)…
    let ten_k_same_cohort = run_config(&manifest, 10_000, 1e-2, rounds);
    // …and the same participation from the smaller population (cohort 1):
    // the resident floor.
    let ten_k_sparse = run_config(&manifest, 10_000, 1e-4, rounds);

    assert_eq!(million.k, 100, "⌈1e-4 · 1e6⌉ must be 100");
    assert_eq!(ten_k_same_cohort.k, 100, "⌈1e-2 · 1e4⌉ must be 100");
    anyhow::ensure!(
        million.peak_resident_bytes == ten_k_same_cohort.peak_resident_bytes,
        "resident population state leaked an O(N) term: N=1e6 peaks at {} B, N=1e4 at {} B \
         for the same cohort of 100",
        million.peak_resident_bytes,
        ten_k_same_cohort.peak_resident_bytes
    );
    anyhow::ensure!(
        ten_k_sparse.peak_resident_bytes < million.peak_resident_bytes,
        "a cohort of {} must hold less resident state than a cohort of 100",
        ten_k_sparse.k
    );

    println!("== pure derivations at N = 10^6 ==");
    let pop = million_population();
    let cohort_bench = bench(
        "cohort_enumeration/N=1e6,K=100",
        benchlib::iters(10, 2),
        benchlib::iters(200, 5),
        || pop.cohort(7),
    );
    let lookup_bench = bench(
        "capacity+gain_lookup/N=1e6",
        benchlib::iters(10, 2),
        benchlib::iters(200, 5),
        || {
            let mut acc = 0.0f64;
            for i in [0u64, 314_159, 999_999] {
                acc += pop.capacity(i) + pop.gain_at(3, i);
            }
            acc
        },
    );

    let mut runs = BTreeMap::new();
    runs.insert("n1e6_r1e-4".to_string(), million.json());
    runs.insert("n1e4_r1e-2".to_string(), ten_k_same_cohort.json());
    runs.insert("n1e4_r1e-4".to_string(), ten_k_sparse.json());
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("virtual_population".to_string()));
    root.insert("quick".to_string(), Json::Bool(benchlib::quick()));
    root.insert("rounds".to_string(), Json::Num(rounds as f64));
    root.insert(
        "o_cohort_bound_verified".to_string(),
        Json::Bool(million.peak_resident_bytes == ten_k_same_cohort.peak_resident_bytes),
    );
    root.insert("runs".to_string(), Json::Obj(runs));
    root.insert(
        "cohort_enumeration_p50_ns".to_string(),
        Json::Num(cohort_bench.p50_ns),
    );
    root.insert(
        "scattered_lookup_p50_ns".to_string(),
        Json::Num(lookup_bench.p50_ns),
    );
    let out = std::env::var("SFLGA_BENCH_OUT").unwrap_or_else(|_| "BENCH_population.json".into());
    std::fs::write(&out, Json::Obj(root).to_string() + "\n")?;
    println!("summary written to {out}");
    Ok(())
}

/// A standalone million-client population for the derivation benches.
fn million_population() -> sfl_ga::coordinator::Population {
    sfl_ga::coordinator::Population::new(
        29,
        1_000_000,
        ScenarioConfig {
            partition: Partition::Dirichlet(0.3),
            participation: 1e-4,
            straggler: StragglerConfig { frac: 0.1, factor: 4.0 },
        },
        Default::default(),
        Default::default(),
    )
    .expect("population")
}
