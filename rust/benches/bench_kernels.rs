//! Native-kernel micro-benchmarks: per-op wall-clock and GFLOP/s for the
//! conv2d and dense forward/backward kernels at the builtin manifest's
//! layer shapes, scalar reference vs the im2col+GEMM fast path — the perf
//! trajectory seed for the runtime layer (ISSUE 4 acceptance: ≥2×
//! single-thread on conv fwd+bwd).
//!
//! Emits a machine-readable summary to `BENCH_kernels.json` (override the
//! path with `SFLGA_BENCH_OUT`, same convention as `bench_parallel`).
//! Everything runs single-threaded: this measures the kernels, not the
//! round engine's fan-out (that is `bench_parallel`'s job).

use std::collections::BTreeMap;

use sfl_ga::benchlib::{self, bench};
use sfl_ga::model::Manifest;
use sfl_ga::runtime::native::gemm::{self, Epilogue, MatView, Tier};
use sfl_ga::runtime::native::ops::{self, Geom};
use sfl_ga::runtime::native::reference;
use sfl_ga::runtime::Scratch;
use sfl_ga::util::json::Json;

/// The deterministic dyadic generator the golden tests use: dense values
/// in [-0.5, 0.5), so the reference's zero-skip heuristic sees realistic
/// (almost-never-zero) raw inputs.
fn gen_vec(offset: u64, n: usize) -> Vec<f32> {
    (0..n as u64)
        .map(|j| {
            let h = ((offset + j) as u32).wrapping_mul(2654435761);
            ((h >> 16) & 0xFF) as f32 / 256.0 - 0.5
        })
        .collect()
}

/// One benchmarked layer op: name, total FLOPs, and the two paths' times.
struct OpRow {
    name: String,
    flops: f64,
    scalar_ns: f64,
    gemm_ns: f64,
}

impl OpRow {
    fn json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("flops".to_string(), Json::Num(self.flops));
        m.insert("scalar_ns".to_string(), Json::Num(self.scalar_ns));
        m.insert("gemm_ns".to_string(), Json::Num(self.gemm_ns));
        m.insert("speedup".to_string(), Json::Num(self.scalar_ns / self.gemm_ns));
        m.insert("gflops_scalar".to_string(), Json::Num(self.flops / self.scalar_ns));
        m.insert("gflops_gemm".to_string(), Json::Num(self.flops / self.gemm_ns));
        Json::Obj(m)
    }
}

fn check_close(tag: &str, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= 1e-4 * (1.0 + y.abs()),
            "{tag}[{i}]: fast {x} vs reference {y}"
        );
    }
}

fn main() -> anyhow::Result<()> {
    // Quick mode (CI bench-smoke): small batches keep the scalar reference
    // path affordable; the JSON's `quick` flag marks the numbers.
    let manifest = if benchlib::quick() {
        Manifest::builtin_with_batches(8, 32)
    } else {
        Manifest::builtin()
    };
    let spec = manifest.for_dataset("mnist")?.clone();
    let b = spec.train_batch;
    let conv_iters = benchlib::iters(3, 1);
    let dense_iters = benchlib::iters(8, 3);
    println!("== native kernels: scalar reference vs im2col+GEMM (batch {b}) ==");

    let mut scratch = Scratch::new();
    let mut rows: Vec<OpRow> = Vec::new();
    let (mut conv_scalar_ns, mut conv_gemm_ns) = (0.0f64, 0.0f64);

    // Walk the manifest blocks exactly like NativeBackend::new does.
    let (mut h, mut w, mut c) =
        (spec.input_shape[0], spec.input_shape[1], spec.input_shape[2]);
    for bi in 0..spec.params.len() / 2 {
        let wshape = &spec.params[2 * bi].shape;
        let name = spec.params[2 * bi].name.trim_end_matches("_w").to_string();
        match wshape.len() {
            4 => {
                let (k, oc) = (wshape[0], wshape[3]);
                let g = Geom { b, h, w, c };
                let x = gen_vec(1_000_000 * bi as u64, g.len());
                let wt = gen_vec(1_000_000 * bi as u64 + 500_000, k * k * c * oc);
                let bias = gen_vec(1_000_000 * bi as u64 + 900_000, oc);
                let d_out = gen_vec(1_000_000 * bi as u64 + 950_000, b * h * w * oc);
                // 2 FLOPs (mul+add) per tap per output element.
                let fwd_flops = 2.0 * (b * h * w * k * k * c * oc) as f64;

                check_close(
                    &format!("{name}_fwd"),
                    &ops::conv2d_fwd(&mut scratch, &x, g, &wt, k, oc, &bias, true),
                    &reference::conv2d_fwd(&x, g, &wt, k, oc, &bias, true),
                );
                let s = bench(&format!("{name}_fwd/scalar"), 1, conv_iters, || {
                    reference::conv2d_fwd(&x, g, &wt, k, oc, &bias, true)
                });
                let f = bench(&format!("{name}_fwd/gemm"), 1, conv_iters, || {
                    ops::conv2d_fwd(&mut scratch, &x, g, &wt, k, oc, &bias, true)
                });
                println!("    -> speedup {:.2}x", s.mean_ns / f.mean_ns);
                conv_scalar_ns += s.mean_ns;
                conv_gemm_ns += f.mean_ns;
                rows.push(OpRow {
                    name: format!("{name}_fwd"),
                    flops: fwd_flops,
                    scalar_ns: s.mean_ns,
                    gemm_ns: f.mean_ns,
                });

                let s = bench(&format!("{name}_bwd/scalar"), 1, conv_iters, || {
                    reference::conv2d_bwd(&x, g, &wt, k, oc, &d_out)
                });
                let f = bench(&format!("{name}_bwd/gemm"), 1, conv_iters, || {
                    ops::conv2d_bwd(&mut scratch, &x, g, &wt, k, oc, &d_out)
                });
                println!("    -> speedup {:.2}x", s.mean_ns / f.mean_ns);
                conv_scalar_ns += s.mean_ns;
                conv_gemm_ns += f.mean_ns;
                rows.push(OpRow {
                    name: format!("{name}_bwd"),
                    flops: 2.0 * fwd_flops, // d_x and d_w GEMMs
                    scalar_ns: s.mean_ns,
                    gemm_ns: f.mean_ns,
                });
                h /= 2;
                w /= 2;
                c = oc;
            }
            2 => {
                let (din, dout) = (wshape[0], wshape[1]);
                let x = gen_vec(2_000_000 * bi as u64, b * din);
                let wt = gen_vec(2_000_000 * bi as u64 + 500_000, din * dout);
                let bias = gen_vec(2_000_000 * bi as u64 + 900_000, dout);
                let d_out = gen_vec(2_000_000 * bi as u64 + 950_000, b * dout);
                let fwd_flops = 2.0 * (b * din * dout) as f64;

                check_close(
                    &format!("{name}_fwd"),
                    &ops::dense_fwd(&mut scratch, &x, b, din, dout, &wt, &bias, true),
                    &reference::dense_fwd(&x, b, din, dout, &wt, &bias, true),
                );
                let s = bench(&format!("{name}_fwd/scalar"), 2, dense_iters, || {
                    reference::dense_fwd(&x, b, din, dout, &wt, &bias, true)
                });
                let f = bench(&format!("{name}_fwd/gemm"), 2, dense_iters, || {
                    ops::dense_fwd(&mut scratch, &x, b, din, dout, &wt, &bias, true)
                });
                println!("    -> speedup {:.2}x", s.mean_ns / f.mean_ns);
                rows.push(OpRow {
                    name: format!("{name}_fwd"),
                    flops: fwd_flops,
                    scalar_ns: s.mean_ns,
                    gemm_ns: f.mean_ns,
                });

                let s = bench(&format!("{name}_bwd/scalar"), 2, dense_iters, || {
                    reference::dense_bwd(&x, b, din, dout, &wt, &d_out)
                });
                let f = bench(&format!("{name}_bwd/gemm"), 2, dense_iters, || {
                    ops::dense_bwd(&mut scratch, &x, b, din, dout, &wt, &d_out)
                });
                println!("    -> speedup {:.2}x", s.mean_ns / f.mean_ns);
                rows.push(OpRow {
                    name: format!("{name}_bwd"),
                    flops: 2.0 * fwd_flops,
                    scalar_ns: s.mean_ns,
                    gemm_ns: f.mean_ns,
                });
                h = 1;
                w = 1;
                c = dout;
            }
            r => anyhow::bail!("unsupported weight rank {r}"),
        }
    }

    // Transformer kernels at the zoo's `txf` mnist shape (28x28 -> 49
    // tokens of width 32, 2 heads): layernorm and softmax-attention
    // forward/backward, fast path vs the f64 scalar reference — the same
    // two-path contract the conv/dense rows pin, extended to the kernels
    // the transformer architectures run on.  The bench-smoke lane keys on
    // the `txf_*` rows below, so these cannot silently drop out.
    let (t, dm, heads) = (49usize, 32usize, 2usize);
    let rows_ln = b * t;
    println!("== transformer kernels: scalar reference vs fast path (b {b}, t {t}, dm {dm}) ==");
    let gamma = gen_vec(51_000_000, dm);
    let beta = gen_vec(51_100_000, dm);
    let lx = gen_vec(51_200_000, rows_ln * dm);
    let ldy = gen_vec(51_300_000, rows_ln * dm);
    let (lo_f, lm_f, lr_f) = ops::layernorm_fwd(&lx, rows_ln, dm, &gamma, &beta);
    let (lo_r, lm_r, lr_r) = reference::layernorm_fwd(&lx, rows_ln, dm, &gamma, &beta);
    check_close("txf_layernorm_fwd", &lo_f, &lo_r);
    let s = bench("txf_layernorm_fwd/scalar", 2, dense_iters, || {
        reference::layernorm_fwd(&lx, rows_ln, dm, &gamma, &beta)
    });
    let f = bench("txf_layernorm_fwd/fast", 2, dense_iters, || {
        ops::layernorm_fwd(&lx, rows_ln, dm, &gamma, &beta)
    });
    // ~8 FLOPs/element: mean, variance, normalize, scale-shift passes.
    let ln_flops = 8.0 * (rows_ln * dm) as f64;
    rows.push(OpRow {
        name: "txf_layernorm_fwd".into(),
        flops: ln_flops,
        scalar_ns: s.mean_ns,
        gemm_ns: f.mean_ns,
    });
    check_close(
        "txf_layernorm_bwd",
        &ops::layernorm_bwd(&lx, &lm_f, &lr_f, &gamma, rows_ln, dm, &ldy).0,
        &reference::layernorm_bwd(&lx, &lm_r, &lr_r, &gamma, rows_ln, dm, &ldy).0,
    );
    let s = bench("txf_layernorm_bwd/scalar", 2, dense_iters, || {
        reference::layernorm_bwd(&lx, &lm_r, &lr_r, &gamma, rows_ln, dm, &ldy)
    });
    let f = bench("txf_layernorm_bwd/fast", 2, dense_iters, || {
        ops::layernorm_bwd(&lx, &lm_f, &lr_f, &gamma, rows_ln, dm, &ldy)
    });
    rows.push(OpRow {
        name: "txf_layernorm_bwd".into(),
        flops: 1.5 * ln_flops,
        scalar_ns: s.mean_ns,
        gemm_ns: f.mean_ns,
    });

    let q = gen_vec(53_000_000, b * t * dm);
    let k = gen_vec(53_100_000, b * t * dm);
    let v = gen_vec(53_200_000, b * t * dm);
    let d_concat = gen_vec(53_300_000, b * t * dm);
    let (cat_f, probs_f) = ops::mhsa_fwd(&mut scratch, &q, &k, &v, b, t, dm, heads);
    let (cat_r, probs_r) = reference::mhsa_fwd(&q, &k, &v, b, t, dm, heads);
    check_close("txf_attention_fwd", &cat_f, &cat_r);
    let s = bench("txf_attention_fwd/scalar", 2, dense_iters, || {
        reference::mhsa_fwd(&q, &k, &v, b, t, dm, heads)
    });
    let f = bench("txf_attention_fwd/gemm", 2, dense_iters, || {
        ops::mhsa_fwd(&mut scratch, &q, &k, &v, b, t, dm, heads)
    });
    println!("    -> speedup {:.2}x", s.mean_ns / f.mean_ns);
    // QK^T and PV are each 2*b*t*t*dm FLOPs (heads partition dm).
    let att_flops = 4.0 * (b * t * t * dm) as f64;
    rows.push(OpRow {
        name: "txf_attention_fwd".into(),
        flops: att_flops,
        scalar_ns: s.mean_ns,
        gemm_ns: f.mean_ns,
    });
    let (dq_f, _dk, _dv) =
        ops::mhsa_bwd(&mut scratch, &q, &k, &v, &probs_f, &d_concat, b, t, dm, heads);
    let (dq_r, _dk, _dv) = reference::mhsa_bwd(&q, &k, &v, &probs_r, &d_concat, b, t, dm, heads);
    check_close("txf_attention_bwd", &dq_f, &dq_r);
    let s = bench("txf_attention_bwd/scalar", 2, dense_iters, || {
        reference::mhsa_bwd(&q, &k, &v, &probs_r, &d_concat, b, t, dm, heads)
    });
    let f = bench("txf_attention_bwd/gemm", 2, dense_iters, || {
        ops::mhsa_bwd(&mut scratch, &q, &k, &v, &probs_f, &d_concat, b, t, dm, heads)
    });
    println!("    -> speedup {:.2}x", s.mean_ns / f.mean_ns);
    rows.push(OpRow {
        name: "txf_attention_bwd".into(),
        flops: 2.0 * att_flops,
        scalar_ns: s.mean_ns,
        gemm_ns: f.mean_ns,
    });

    let conv_speedup = conv_scalar_ns / conv_gemm_ns;
    println!(
        "conv2d fwd+bwd total: scalar {:.1} ms, gemm {:.1} ms -> {conv_speedup:.2}x \
         (acceptance floor: 2.00x)",
        conv_scalar_ns / 1e6,
        conv_gemm_ns / 1e6
    );
    println!("scratch high-water: {} KiB", scratch.capacity_bytes() / 1024);

    // Tier face-off: the identical blocked GEMM through the portable vs
    // the SIMD microkernel at an fc1-like shape.  On hosts without
    // AVX2+FMA, `Tier::supported` clamps both runs to the portable kernel
    // and the speedup reports ~1.0 (the JSON's `gemm_tier` says which).
    let (tm, tn, tk) = if benchlib::quick() { (64, 128, 512) } else { (256, 512, 3136) };
    let ta = gen_vec(41_000_000, tm * tk);
    let tb = gen_vec(42_000_000, tk * tn);
    let tbias = gen_vec(43_000_000, tn);
    let mut tc = vec![0.0f32; tm * tn];
    let (mut pa, mut pb) = (Vec::new(), Vec::new());
    let tier_iters = benchlib::iters(30, 5);
    let mut tier_ns = [0.0f64; 2];
    for (slot, tier) in [Tier::Portable, Tier::Avx2].into_iter().enumerate() {
        let r = bench(&format!("gemm_{tm}x{tn}x{tk}/{}", tier.name()), 2, tier_iters, || {
            gemm::gemm_with_tier(
                tier,
                &mut tc,
                tm,
                tn,
                tk,
                MatView::rows(&ta, tk),
                MatView::rows(&tb, tn),
                Epilogue::BiasRelu(&tbias),
                false,
                &mut pa,
                &mut pb,
            );
            tc[0]
        });
        tier_ns[slot] = r.mean_ns;
    }
    let simd_speedup = tier_ns[0] / tier_ns[1];
    let active = Tier::Avx2.supported();
    println!("simd tier ({}) vs portable at {tm}x{tn}x{tk}: {simd_speedup:.2}x", active.name());

    let mut ops_json = BTreeMap::new();
    for row in &rows {
        ops_json.insert(row.name.clone(), row.json());
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("native_kernels".to_string()));
    root.insert("quick".to_string(), Json::Bool(benchlib::quick()));
    root.insert("shape_key".to_string(), Json::Str(spec.key.clone()));
    root.insert("train_batch".to_string(), Json::Num(b as f64));
    root.insert("conv_fwd_bwd_speedup".to_string(), Json::Num(conv_speedup));
    root.insert("gemm_tier".to_string(), Json::Str(active.name().to_string()));
    root.insert("simd_vs_portable_speedup".to_string(), Json::Num(simd_speedup));
    root.insert(
        "scratch_bytes".to_string(),
        Json::Num(scratch.capacity_bytes() as f64),
    );
    root.insert("ops".to_string(), Json::Obj(ops_json));
    let out = std::env::var("SFLGA_BENCH_OUT").unwrap_or_else(|_| "BENCH_kernels.json".into());
    std::fs::write(&out, Json::Obj(root).to_string() + "\n")?;
    println!("summary written to {out}");
    Ok(())
}
