//! DDQN benchmarks: act/train-step latency of the pure-Rust agent at the
//! Algorithm-1 configuration (state dim N+1, 64x64 hidden, batch 32).

use sfl_ga::benchlib::{self, bench};
use sfl_ga::ddqn::{DdqnAgent, DdqnConfig, Transition};

fn main() {
    println!("== ddqn ==");
    let cfg = DdqnConfig {
        state_dim: 11,
        num_actions: 4,
        hidden: vec![64, 64],
        batch: 32,
        warmup: 32,
        ..Default::default()
    };
    let mut agent = DdqnAgent::new(cfg, 7);
    let state = vec![0.3f32; 11];
    for i in 0..256 {
        agent.remember(Transition {
            state: state.clone(),
            action: i % 4,
            reward: -(i as f64) * 0.1,
            next_state: state.clone(),
            done: i % 20 == 0,
        });
    }
    bench("act(eps-greedy)", 100, benchlib::iters(2000, 200), || agent.act(&state));
    bench("greedy_forward", 100, benchlib::iters(2000, 200), || agent.greedy(&state));
    bench("train_step(batch=32)", 20, benchlib::iters(300, 30), || agent.train_step());
}
