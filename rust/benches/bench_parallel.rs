//! Parallel round-engine scaling: real wall-clock of one communication
//! round at threads ∈ {1, 2, 4} for SFL-GA and FL on the builtin manifest
//! (native backend, default paper batches), plus the measured speedup vs
//! the serial engine.  A second, *pipelined-chain* variant measures SFL
//! (unicast) at τ = 2 — the configuration where the task-session executor
//! fuses client-fwd → server FP/BP → client-bwd into ONE chain per
//! participant with no phase barriers inside an epoch, so its speedup
//! over threads=1 isolates the win of phase fusion on deep chains.
//! Emits a machine-readable summary to `BENCH_parallel.json` (override
//! the path with `SFLGA_BENCH_OUT`) to seed the perf trajectory across
//! PRs.
//!
//! Training results are bitwise identical at every thread count
//! (`tests/determinism.rs`), so this measures pure systems speedup.

use std::collections::BTreeMap;

use sfl_ga::benchlib::{self, bench};
use sfl_ga::coordinator::{SchemeKind, TrainConfig, Trainer};
use sfl_ga::model::Manifest;
use sfl_ga::util::json::Json;

const CUT: usize = 2;
const CLIENTS: usize = 8;
const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// One-round wall-clock for a (scheme, τ) pair across [`THREAD_COUNTS`],
/// returned as the per-thread JSON block (with speedups vs threads=1).
fn bench_scheme(
    manifest: &Manifest,
    scheme: SchemeKind,
    tau: usize,
    label: &str,
) -> anyhow::Result<BTreeMap<String, Json>> {
    let mut per_thread: BTreeMap<String, Json> = BTreeMap::new();
    let mut serial_mean_ns = 0.0;
    for threads in THREAD_COUNTS {
        let cfg = TrainConfig {
            scheme,
            tau,
            threads,
            rounds: 1_000_000, // never reached; we drive rounds manually
            eval_every: usize::MAX,
            samples_per_client: benchlib::iters(64, 16),
            num_clients: CLIENTS,
            ..Default::default()
        };
        let mut trainer = Trainer::native(manifest, cfg)?;
        let iters = benchlib::iters(4, 2);
        let r = bench(&format!("round/{label}/threads={threads}"), 1, iters, || {
            let st = trainer.draw_channel();
            trainer.run_round(CUT, &st).unwrap().train_loss
        });
        if threads == 1 {
            serial_mean_ns = r.mean_ns;
        }
        let speedup = serial_mean_ns / r.mean_ns;
        println!("    -> speedup vs threads=1: {speedup:.2}x");
        let mut entry = BTreeMap::new();
        entry.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
        entry.insert("p50_ns".to_string(), Json::Num(r.p50_ns));
        entry.insert("min_ns".to_string(), Json::Num(r.min_ns));
        entry.insert("speedup_vs_serial".to_string(), Json::Num(speedup));
        per_thread.insert(format!("threads_{threads}"), Json::Obj(entry));
    }
    Ok(per_thread)
}

fn main() -> anyhow::Result<()> {
    // Quick mode (CI bench-smoke): test-sized batches so a full round is
    // milliseconds — the JSON marks the mode so numbers are never mixed.
    let manifest = if benchlib::quick() {
        Manifest::builtin_with_batches(8, 32)
    } else {
        Manifest::builtin()
    };
    let mut schemes_json: BTreeMap<String, Json> = BTreeMap::new();
    println!("== parallel round engine: one-round wall-clock ==");
    for scheme in [SchemeKind::SflGa, SchemeKind::Fl] {
        let block = bench_scheme(&manifest, scheme, 1, scheme.name())?;
        schemes_json.insert(scheme.name().to_string(), Json::Obj(block));
    }
    // Pipelined-chain variant: unicast SFL at τ = 2 runs each participant
    // as one fused fwd → server → bwd chain per epoch — no phase barrier
    // anywhere inside the epoch, the deepest pipeline the plans express.
    println!("== pipelined fused chains: sfl, tau=2 ==");
    let block = bench_scheme(&manifest, SchemeKind::Sfl, 2, "sfl-fused-tau2")?;
    schemes_json.insert("sfl_fused_tau2".to_string(), Json::Obj(block));

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("parallel_round_engine".to_string()));
    root.insert("quick".to_string(), Json::Bool(benchlib::quick()));
    root.insert("cut".to_string(), Json::Num(CUT as f64));
    root.insert("num_clients".to_string(), Json::Num(CLIENTS as f64));
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    root.insert("host_parallelism".to_string(), Json::Num(host as f64));
    root.insert("schemes".to_string(), Json::Obj(schemes_json));
    let out = std::env::var("SFLGA_BENCH_OUT").unwrap_or_else(|_| "BENCH_parallel.json".into());
    std::fs::write(&out, Json::Obj(root).to_string() + "\n")?;
    println!("summary written to {out}");
    Ok(())
}
