//! End-to-end round benchmarks: real wall time of one communication round
//! per scheme (native-backend compute + aggregation + bookkeeping), plus
//! the per-round hot-path pieces (aggregation saxpy, channel draw,
//! comm/timing models).  This is the paper's Table-less "system cost" view.

use sfl_ga::benchlib::{self, bench};
use sfl_ga::coordinator::{SchemeKind, TrainConfig, Trainer};
use sfl_ga::model::Manifest;
use sfl_ga::tensor;
use sfl_ga::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    println!("== end-to-end rounds ==");
    // Quick mode (CI bench-smoke): test-sized batches, fewer iterations.
    let manifest = if benchlib::quick() {
        Manifest::builtin_with_batches(8, 32)
    } else {
        Manifest::builtin()
    };
    for scheme in SchemeKind::all() {
        let cfg = TrainConfig {
            scheme,
            rounds: 1_000_000, // never reached; we drive rounds manually
            eval_every: usize::MAX,
            samples_per_client: benchlib::iters(64, 16),
            num_clients: 4,
            ..Default::default()
        };
        let mut trainer = Trainer::native(&manifest, cfg)?;
        bench(&format!("round/{}", scheme.name()), 1, benchlib::iters(3, 1), || {
            let st = trainer.draw_channel();
            trainer.run_round(2, &st).unwrap().train_loss
        });
    }

    println!("== hot-path pieces ==");
    let mut rng = Pcg::new(3, 3);
    // Smashed-gradient aggregation at v=2: 10 tensors of 32*3136 floats.
    let parts: Vec<Vec<f32>> = (0..10)
        .map(|_| (0..32 * 3136).map(|_| rng.normal() as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = parts.iter().map(|v| v.as_slice()).collect();
    let rho = vec![0.1f64; 10];
    bench("aggregate_smashed_grads(10x100k)", 10, benchlib::iters(200, 20), || {
        tensor::weighted_sum_flat(&refs, &rho)
    });

    // Server-side model aggregation at v=2 (~1.67M params over 10 parts).
    let model_parts: Vec<Vec<Vec<f32>>> = (0..10)
        .map(|_| vec![(0..1_673_098 / 2).map(|_| rng.normal() as f32).collect::<Vec<f32>>(); 2])
        .collect();
    let model_refs: Vec<&Vec<Vec<f32>>> = model_parts.iter().collect();
    bench("aggregate_server_models(10x1.67M)", 2, benchlib::iters(20, 3), || {
        tensor::weighted_sum(&model_refs, &rho)
    });

    let mut channel = sfl_ga::wireless::Channel::new(Default::default(), 10, 1);
    bench("channel_draw(N=10)", 100, benchlib::iters(5000, 500), || channel.draw_round());
    Ok(())
}
