"""L2 model tests: split/full equivalence, gradient identities, manifest math.

The key reproduction invariant: for every cut v, the split pipeline
(client_fwd -> server_grad -> client_grad) must equal the monolithic
full_grad — i.e. splitting is exact, and the ONLY behavioural difference
between SFL-GA and SFL is which smashed-gradient tensor L3 feeds back.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.layers import DATASET_SHAPE, NUM_CUTS, SPECS, init_params

jax.config.update("jax_platform_name", "cpu")

SPEC = SPECS["28x28x1"]
BATCH = 4


@pytest.fixture(scope="module")
def params():
    return init_params(SPEC, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (BATCH, *SPEC.input_shape), jnp.float32)
    labels = jax.random.randint(ky, (BATCH,), 0, SPEC.classes)
    y1h = jax.nn.one_hot(labels, SPEC.classes, dtype=jnp.float32)
    return x, y1h


@pytest.mark.parametrize("cut", range(1, NUM_CUTS + 1))
def test_split_forward_equals_full(params, batch, cut):
    x, _ = batch
    nc = SPEC.client_param_count(cut)
    (smashed,) = model.client_fwd(SPEC, cut, params[:nc], x)
    assert smashed.shape == SPEC.smashed_shape(cut, BATCH)
    logits_split = model.server_fwd(SPEC, cut, params[nc:], smashed)
    logits_full = model.server_fwd(SPEC, 0, params, x)
    np.testing.assert_allclose(logits_split, logits_full, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("cut", range(1, NUM_CUTS + 1))
def test_split_gradients_equal_full(params, batch, cut):
    """server_grad ∘ client_grad == full_grad (chain rule is exact)."""
    x, y1h = batch
    nc = SPEC.client_param_count(cut)
    (smashed,) = model.client_fwd(SPEC, cut, params[:nc], x)
    loss_s, *rest = model.server_grad(SPEC, cut, params[nc:], smashed, y1h)
    g_ws, g_smashed = rest[:-1], rest[-1]
    g_wc = model.client_grad(SPEC, cut, params[:nc], x, g_smashed)

    loss_f, *g_full = model.full_grad(SPEC, params, x, y1h)
    np.testing.assert_allclose(loss_s, loss_f, rtol=1e-5, atol=1e-6)
    split_grads = list(g_wc) + list(g_ws)
    assert len(split_grads) == len(g_full)
    for a, b in zip(split_grads, g_full):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


def test_gradient_aggregation_linearity(params, batch):
    """Aggregating smashed-gradients then running client_grad equals
    aggregating per-client client-side gradients (eq 5/6 commute):
    the client-side VJP is linear in the cotangent."""
    cut = 2
    x, _ = batch
    nc = SPEC.client_param_count(cut)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    g1 = jax.random.normal(k1, SPEC.smashed_shape(cut, BATCH), jnp.float32)
    g2 = jax.random.normal(k2, SPEC.smashed_shape(cut, BATCH), jnp.float32)
    rho1, rho2 = 0.3, 0.7
    agg = model.client_grad(SPEC, cut, params[:nc], x, rho1 * g1 + rho2 * g2)
    sep1 = model.client_grad(SPEC, cut, params[:nc], x, g1)
    sep2 = model.client_grad(SPEC, cut, params[:nc], x, g2)
    for a, b1, b2 in zip(agg, sep1, sep2):
        np.testing.assert_allclose(a, rho1 * b1 + rho2 * b2, rtol=1e-3, atol=1e-5)


def test_cross_entropy_matches_manual():
    logits = jnp.array([[2.0, 0.0, -1.0], [0.5, 0.5, 0.5]])
    y = jnp.array([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0]])
    want = -np.mean(
        [
            np.log(np.exp(2.0) / np.exp([2.0, 0.0, -1.0]).sum()),
            np.log(np.exp(0.5) / np.exp([0.5, 0.5, 0.5]).sum()),
        ]
    )
    np.testing.assert_allclose(model.cross_entropy(logits, y), want, rtol=1e-6)


def test_eval_batch_counts_correct(params, batch):
    x, y1h = batch
    loss, correct = model.eval_batch(SPEC, params, x, y1h)
    logits = model.server_fwd(SPEC, 0, params, x)
    want = np.sum(np.argmax(logits, -1) == np.argmax(y1h, -1))
    assert float(correct) == pytest.approx(want)
    assert float(loss) > 0.0


def test_training_reduces_loss(params, batch):
    """A few SGD steps on full_grad must reduce the loss — the whole
    compute stack is trainable end-to-end."""
    x, y1h = batch
    w = [p for p in params]
    loss0, *g = model.full_grad(SPEC, w, x, y1h)
    for _ in range(8):
        loss, *g = model.full_grad(SPEC, w, x, y1h)
        w = [wi - 0.01 * gi for wi, gi in zip(w, g)]
    loss1, *_ = model.full_grad(SPEC, w, x, y1h)
    assert float(loss1) < float(loss0)


# ------------------------------------------------------------ spec math

@pytest.mark.parametrize("key", list(SPECS))
def test_phi_monotone_in_cut(key):
    spec = SPECS[key]
    phis = [spec.phi(v) for v in range(1, NUM_CUTS + 1)]
    assert all(a <= b for a, b in zip(phis, phis[1:]))
    assert phis[-1] < spec.total_params  # server always keeps the head


@pytest.mark.parametrize("key", list(SPECS))
def test_flops_split_sums_to_total(key):
    spec = SPECS[key]
    total_f = sum(spec.block_flops_fwd())
    total_b = sum(spec.block_flops_bwd())
    for v in range(1, NUM_CUTS + 1):
        fl = spec.flops(v)
        assert fl["client_fwd"] + fl["server_fwd"] == total_f
        assert fl["client_bwd"] + fl["server_bwd"] == total_b


def test_known_phi_values_mnist():
    """DESIGN.md table: φ(1)=832, φ(2)=52 096, φ(3)=1 658 240, φ(4)=1 723 904."""
    spec = SPECS["28x28x1"]
    assert [spec.phi(v) for v in (1, 2, 3, 4)] == [832, 52096, 1658240, 1723904]


def test_dataset_shape_mapping_complete():
    assert set(DATASET_SHAPE) == {"mnist", "fmnist", "cifar10"}
    assert all(v in SPECS for v in DATASET_SHAPE.values())


@pytest.mark.parametrize("cut", range(1, NUM_CUTS + 1))
def test_make_role_shapes_consistent(cut):
    """Example-arg shapes fed to jit.lower must match what the role expects."""
    fn, args = model.make_role(SPEC, "server_grad", cut, 8)
    out = jax.eval_shape(fn, *args)
    # loss, g_ws..., g_smashed
    n_server = len(SPEC.param_specs()) - SPEC.client_param_count(cut)
    assert len(out) == 1 + n_server + 1
    assert out[0].shape == ()
    assert tuple(out[-1].shape) == SPEC.smashed_shape(cut, 8)
