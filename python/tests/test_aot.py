"""AOT path tests: HLO-text emission and manifest structure."""

import json

import jax
import pytest

from compile import aot, model
from compile.layers import NUM_CUTS, SPECS

jax.config.update("jax_platform_name", "cpu")

SPEC = SPECS["28x28x1"]


def test_to_hlo_text_emits_parseable_module():
    fn, args = model.make_role(SPEC, "client_fwd", 1, 4)
    text = aot.lower_role(SPEC, "client_fwd", 1, 4)
    assert text.startswith("HloModule"), text[:40]
    # return_tuple=True => a tuple root somewhere in the entry computation.
    assert "ENTRY" in text
    assert "tuple(" in text or "(f32[" in text


def test_lower_role_client_fwd_has_params_plus_input():
    text = aot.lower_role(SPEC, "client_fwd", 2, 8)
    # cut 2: 4 client params + x = 5 parameters in the ENTRY computation
    # (nested while-body computations declare their own parameters, so
    # count only after the ENTRY marker).
    entry = text[text.index("ENTRY") :]
    count = entry.count("parameter(")
    assert count == 5, f"expected 5 entry parameters, found {count}"


@pytest.mark.parametrize("role", ["server_grad", "client_grad", "full_grad", "eval"])
def test_lower_all_roles_smoke(role):
    cut = 0 if role in ("full_grad", "eval") else 3
    batch = 16 if role == "eval" else 4
    text = aot.lower_role(SPEC, role, cut, batch)
    assert text.startswith("HloModule")
    assert len(text) > 500


def test_shape_manifest_structure():
    files = {}
    for cut in range(1, NUM_CUTS + 1):
        for role in aot.ROLES_PER_CUT:
            files[(cut, role)] = f"f_{cut}_{role}"
    for role in aot.ROLES_GLOBAL:
        files[(0, role)] = f"f_{role}"
    m = aot.shape_manifest(SPEC, files)
    assert m["total_params"] == SPEC.total_params
    assert len(m["params"]) == 10
    assert set(m["cuts"]) == {"1", "2", "3", "4"}
    c2 = m["cuts"]["2"]
    assert c2["phi"] == SPEC.phi(2)
    assert c2["smashed_shape"] == [aot.TRAIN_BATCH, 7, 7, 64]
    assert c2["artifacts"]["client_fwd"] == "f_2_client_fwd"
    # JSON-serializable end to end.
    json.dumps(m)


def test_manifest_flops_are_consistent():
    files = {(c, r): "x" for c in range(1, NUM_CUTS + 1) for r in aot.ROLES_PER_CUT}
    files.update({(0, r): "x" for r in aot.ROLES_GLOBAL})
    m = aot.shape_manifest(SPEC, files)
    totals = set()
    for cut in m["cuts"].values():
        totals.add(cut["flops_client_fwd"] + cut["flops_server_fwd"])
    assert len(totals) == 1, "fwd FLOPs must sum to the same total at every cut"
