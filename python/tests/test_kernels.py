"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp ref oracle.

Hypothesis sweeps shapes/dtypes; every property asserts allclose on both
values and gradients (where the kernel defines a VJP).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, fused, matmul, pool, ref

jax.config.update("jax_platform_name", "cpu")

DIM = st.integers(min_value=1, max_value=48)
SMALL = st.integers(min_value=1, max_value=12)
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- matmul

@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1), dtype=DTYPES)
def test_matmul_matches_ref(m, k, n, seed, dtype):
    kx, kw = _keys(seed, 2)
    x, w = _rand(kx, (m, k), dtype), _rand(kw, (k, n), dtype)
    got = matmul.matmul(x, w)
    want = ref.matmul(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-4)


@settings(max_examples=15, deadline=None)
@given(m=SMALL, k=SMALL, n=SMALL, seed=st.integers(0, 2**31 - 1))
def test_matmul_grads_match_ref(m, k, n, seed):
    kx, kw, kg = _keys(seed, 3)
    x, w = _rand(kx, (m, k)), _rand(kw, (k, n))
    cot = _rand(kg, (m, n))

    def loss_kernel(x, w):
        return jnp.sum(matmul.matmul(x, w) * cot)

    def loss_ref(x, w):
        return jnp.sum(ref.matmul(x, w) * cot)

    gx, gw = jax.grad(loss_kernel, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)


def test_matmul_blocked_large_exact_tiles():
    """Shapes that are exact multiples of the 128 default blocks."""
    kx, kw = _keys(7, 2)
    x, w = _rand(kx, (256, 384)), _rand(kw, (384, 128))
    np.testing.assert_allclose(matmul.matmul(x, w), ref.matmul(x, w),
                               rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul.matmul_raw(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


# ---------------------------------------------------------------- dense

@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1),
       act=st.sampled_from(["relu", "none"]))
def test_dense_matches_ref(m, k, n, seed, act):
    kx, kw, kb = _keys(seed, 3)
    x, w, b = _rand(kx, (m, k)), _rand(kw, (k, n)), _rand(kb, (n,))
    got = fused.dense(x, w, b, act)
    want = ref.dense(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(m=SMALL, k=SMALL, n=SMALL, seed=st.integers(0, 2**31 - 1),
       act=st.sampled_from(["relu", "none"]))
def test_dense_grads_match_ref(m, k, n, seed, act):
    kx, kw, kb, kg = _keys(seed, 4)
    x, w, b = _rand(kx, (m, k)), _rand(kw, (k, n)), _rand(kb, (n,))
    cot = _rand(kg, (m, n))

    def loss_kernel(x, w, b):
        return jnp.sum(fused.dense(x, w, b, act) * cot)

    def loss_ref(x, w, b):
        return jnp.sum(ref.dense(x, w, b, act) * cot)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_dense_relu_clamps_negative():
    x = jnp.array([[-1.0, 1.0]])
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2)
    out = fused.dense(x, w, b, "relu")
    np.testing.assert_allclose(out, [[0.0, 1.0]], atol=1e-6)


# ---------------------------------------------------------------- conv2d

@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 3), h=st.integers(2, 10), w=st.integers(2, 10),
       cin=st.integers(1, 4), cout=st.integers(1, 6),
       k=st.sampled_from([1, 3, 5]), seed=st.integers(0, 2**31 - 1),
       act=st.sampled_from(["relu", "none"]))
def test_conv2d_matches_ref(b, h, w, cin, cout, k, seed, act):
    kx, kw, kb = _keys(seed, 3)
    x = _rand(kx, (b, h, w, cin))
    wt = _rand(kw, (k, k, cin, cout)) * 0.3
    bias = _rand(kb, (cout,)) * 0.1
    got = conv.conv2d(x, wt, bias, act=act)
    want = ref.conv2d(x, wt, bias, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_conv2d_grads_match_ref(seed):
    kx, kw, kb, kg = _keys(seed, 4)
    x = _rand(kx, (2, 6, 6, 3))
    wt = _rand(kw, (3, 3, 3, 4)) * 0.3
    bias = _rand(kb, (4,)) * 0.1
    cot = _rand(kg, (2, 6, 6, 4))

    def loss_kernel(x, wt, bias):
        return jnp.sum(conv.conv2d(x, wt, bias) * cot)

    def loss_ref(x, wt, bias):
        return jnp.sum(ref.conv2d(x, wt, bias) * cot)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, wt, bias)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, wt, bias)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, rtol=1e-3, atol=1e-4)


def test_im2col_layout_matches_hwio_flatten():
    """patches @ w.reshape(-1, cout) must equal the reference conv."""
    kx, kw = _keys(3, 2)
    x = _rand(kx, (1, 4, 4, 2))
    wt = _rand(kw, (3, 3, 2, 5))
    patches = conv.im2col(x, 3, 3)
    out = (patches @ wt.reshape(-1, 5)).reshape(1, 4, 4, 5)
    want = ref.conv2d(x, wt, jnp.zeros(5))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------- maxpool

@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 3), h=st.sampled_from([2, 4, 8, 14]),
       w=st.sampled_from([2, 4, 8, 14]), c=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_maxpool_matches_ref(b, h, w, c, seed):
    x = _rand(_keys(seed, 1)[0], (b, h, w, c))
    np.testing.assert_allclose(pool.maxpool2x2(x), ref.maxpool2x2(x),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_maxpool_grads_match_ref(seed):
    kx, kg = _keys(seed, 2)
    x = _rand(kx, (2, 4, 4, 3))
    cot = _rand(kg, (2, 2, 2, 3))

    def loss_kernel(x):
        return jnp.sum(pool.maxpool2x2(x) * cot)

    def loss_ref(x):
        return jnp.sum(ref.maxpool2x2(x) * cot)

    np.testing.assert_allclose(jax.grad(loss_kernel)(x), jax.grad(loss_ref)(x),
                               rtol=1e-5, atol=1e-5)


def test_maxpool_tie_splits_gradient():
    """Equal values in a window split the incoming gradient evenly."""
    x = jnp.ones((1, 2, 2, 1))
    g = jax.grad(lambda x: jnp.sum(pool.maxpool2x2(x)))(x)
    np.testing.assert_allclose(g, jnp.full((1, 2, 2, 1), 0.25), atol=1e-6)


def test_maxpool_odd_shape_rejected():
    with pytest.raises(ValueError):
        pool.maxpool2x2_raw(jnp.zeros((1, 3, 4, 1)))
