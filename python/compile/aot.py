"""AOT compile path: lower every (shape, cut, role) to HLO text + manifest.

Run ONCE via `make artifacts`; python never appears on the request path.

Interchange is HLO *text* (not serialized HloModuleProto): jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts are emitted per *shape key* ("28x28x1", "32x32x3") — mnist and
fashion-mnist share identical HLO; the manifest maps each logical dataset to
its shape key so the Rust side resolves files without duplication.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

from jax._src.lib import xla_client as xc

from . import model
from .layers import DATASET_SHAPE, NUM_CUTS, SPECS, ModelSpec

TRAIN_BATCH = 32
EVAL_BATCH = 256

ROLES_PER_CUT = ("client_fwd", "server_grad", "client_grad")
ROLES_GLOBAL = ("full_grad", "eval")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_role(spec: ModelSpec, role: str, cut: int, batch: int) -> str:
    fn, example_args = model.make_role(spec, role, cut, batch)
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def shape_manifest(spec: ModelSpec, files: dict) -> dict:
    cuts = {}
    for cut in range(1, NUM_CUTS + 1):
        fl = spec.flops(cut)
        cuts[str(cut)] = {
            "phi": spec.phi(cut),
            "client_params": spec.client_param_count(cut),
            "smashed_shape": list(spec.smashed_shape(cut, TRAIN_BATCH)),
            "flops_client_fwd": fl["client_fwd"],
            "flops_client_bwd": fl["client_bwd"],
            "flops_server_fwd": fl["server_fwd"],
            "flops_server_bwd": fl["server_bwd"],
            "artifacts": {r: files[(cut, r)] for r in ROLES_PER_CUT},
        }
    return {
        "input_shape": list(spec.input_shape),
        "classes": spec.classes,
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "total_params": spec.total_params,
        "params": [
            {"name": p.name, "shape": list(p.shape), "block": p.block}
            for p in spec.param_specs()
        ],
        "cuts": cuts,
        "artifacts": {r: files[(0, r)] for r in ROLES_GLOBAL},
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--shapes",
        nargs="*",
        default=list(SPECS),
        help="shape keys to compile (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": 1, "train_batch": TRAIN_BATCH, "eval_batch": EVAL_BATCH,
                "shapes": {}, "datasets": {}}
    t0 = time.time()
    for key in args.shapes:
        spec = SPECS[key]
        files = {}
        jobs = [(cut, role) for cut in range(1, NUM_CUTS + 1) for role in ROLES_PER_CUT]
        jobs += [(0, role) for role in ROLES_GLOBAL]
        for cut, role in jobs:
            batch = EVAL_BATCH if role == "eval" else TRAIN_BATCH
            tag = f"{key}_v{cut}_{role}" if cut else f"{key}_{role}"
            fname = f"{tag}.hlo.txt"
            t = time.time()
            text = lower_role(spec, role, cut, batch)
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            files[(cut, role)] = fname
            print(f"  [{time.time() - t0:7.1f}s] {fname:44s} "
                  f"{len(text) / 1e6:6.2f} MB  ({time.time() - t:.1f}s)",
                  file=sys.stderr)
        manifest["shapes"][key] = shape_manifest(spec, files)

    for ds, key in DATASET_SHAPE.items():
        if key in manifest["shapes"]:
            manifest["datasets"][ds] = key

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['shapes'])} shapes "
          f"in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
