"""Blocked Pallas matmul — the L1 hot-spot kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles (M, N, K) into
MXU-friendly blocks; each grid step loads one (bm, bk) x-tile and one
(bk, bn) w-tile into VMEM via BlockSpec and accumulates a (bm, bn) output
tile in f32.  The K axis is the innermost grid dimension so the output tile
stays VMEM-resident across the whole reduction (the classic systolic-array
schedule; what a CUDA kernel would do with threadblock tiles + shared
memory, expressed here with BlockSpec index maps).

Autodiff: ``pallas_call`` has no VJP rule, so :func:`matmul` is wrapped in
``jax.custom_vjp`` with the backward pass itself expressed as two Pallas
matmuls (dx = g @ w.T, dw = x.T @ g) — gradients of the split model never
leave the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block-shape policy.  On a real TPU the right tiles are MXU-shaped
# (128x128x128) so the (bm, bk)+(bk, bn) working set stays in VMEM.  Under
# interpret=True on CPU-PJRT, every grid step costs ~1 ms of interpreter
# overhead (dynamic-slice + copy per step), so the fast configuration is
# ONE grid step with whole-array blocks — same kernel, degenerate grid.
# `SFLGA_TILE` (read at AOT/lowering time) restores fixed tiling to inspect
# the TPU schedule; DESIGN.md §Perf records the measured difference.
import os

_TILE = int(os.environ.get("SFLGA_TILE", "0"))  # 0 = whole-array blocks
TPU_TILE = 128  # the MXU edge used when SFLGA_TILE=128

DEFAULT_BM = _TILE if _TILE > 0 else None
DEFAULT_BN = _TILE if _TILE > 0 else None
DEFAULT_BK = _TILE if _TILE > 0 else None


def _ceil_to(x: int, b: int) -> int:
    return ((x + b - 1) // b) * b


def _resolve_block(b, dim: int) -> int:
    """None -> cover the whole (8-aligned) dimension in one step."""
    padded = _ceil_to(dim, 8)
    return padded if b is None else min(b, padded)


def _mm_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One grid step: accumulate x_tile @ w_tile into the output tile.

    The output BlockSpec maps every k index to the same (i, j) tile, so
    o_ref acts as the VMEM accumulator across the K reduction.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul_raw(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int | None = DEFAULT_BM,
    bn: int | None = DEFAULT_BN,
    bk: int | None = DEFAULT_BK,
) -> jax.Array:
    """Pallas blocked matmul without a VJP rule (padding handled here).

    Inputs of any (m, k) x (k, n) shape; internally zero-padded to block
    multiples (zero rows/cols contribute nothing to the product).
    """
    m, kdim = x.shape
    k2, n = w.shape
    if kdim != k2:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {w.shape}")

    bm = _resolve_block(bm, m)
    bn = _resolve_block(bn, n)
    bk = _resolve_block(bk, kdim)

    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(kdim, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - kdim))) if (mp, kp) != (m, kdim) else x
    wp = jnp.pad(w, ((0, kp - kdim), (0, np_ - n))) if (kp, np_) != (kdim, n) else w

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32), wp.astype(jnp.float32))
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable Pallas matmul (backward = two Pallas matmuls)."""
    return matmul_raw(x, w)


def _matmul_fwd(x, w):
    return matmul_raw(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = matmul_raw(g, w.T)
    dw = matmul_raw(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
