"""Conv2D expressed as im2col + the fused Pallas matmul.

TPU adaptation: direct sliding-window convolution is a GPU idiom; the
TPU-native formulation is im2col → one big MXU matmul.  Patch extraction is
a pure data-movement op (25 static shifted slices for a 5x5 SAME conv) that
XLA fuses into the surrounding graph; all FLOPs land in the Pallas
``dense`` kernel, so the conv's hot loop runs on the (simulated) MXU.

Patch layout matches ``w.reshape(kh*kw*cin, cout)`` for HWIO weights.
Differentiability comes for free: slicing/padding are native JAX ops and
``dense`` carries its own Pallas VJP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .fused import Activation, dense


def im2col(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """Extract SAME-padded (kh, kw) patches from NHWC input.

    Returns (batch * h * w, kh * kw * cin), rows ordered (b, y, x) and
    columns ordered (dy, dx, cin) — matching HWIO weight flattening.
    """
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(xp[:, dy : dy + h, dx : dx + w, :])
    # (b, h, w, kh*kw, c) -> (b*h*w, kh*kw*c)
    patches = jnp.stack(cols, axis=3)
    return patches.reshape(b * h * w, kh * kw * c)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array,
    *,
    act: Activation = "none",
) -> jax.Array:
    """SAME conv, stride 1, NHWC x HWIO -> NHWC, via im2col + Pallas dense."""
    b, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    if cin != cin2 or bias.shape != (cout,):
        raise ValueError(f"conv2d shape mismatch: {x.shape} * {w.shape} + {bias.shape}")
    patches = im2col(x, kh, kw)
    wmat = w.reshape(kh * kw * cin, cout)
    out = dense(patches, wmat, bias, act)
    return out.reshape(b, h, wd, cout)
