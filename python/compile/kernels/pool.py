"""2x2 max-pool Pallas kernel (stride 2, NHWC).

One grid step per example: the (h, w, c) block stays in VMEM and the
windowed max is a reshape + reduce — no HBM traffic between the loads and
the single pooled store.

The custom VJP routes the upstream gradient to the argmax positions
(ties split evenly), computed with plain jnp ops on the saved forward
output — max-pool backward is pure data movement, so there is nothing for
the MXU to do and a Pallas backward kernel would buy nothing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref):
    x = x_ref[...]  # (1, h, w, c)
    _, h, w, c = x.shape
    o_ref[...] = x.reshape(1, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def maxpool2x2_raw(x: jax.Array) -> jax.Array:
    """Forward-only Pallas max-pool; input NHWC with even h, w."""
    b, h, w, c = x.shape
    if h % 2 or w % 2:
        raise ValueError(f"maxpool2x2 needs even spatial dims, got {x.shape}")
    return pl.pallas_call(
        _pool_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, h // 2, w // 2, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h // 2, w // 2, c), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))


@jax.custom_vjp
def maxpool2x2(x: jax.Array) -> jax.Array:
    """Differentiable 2x2/stride-2 max pool."""
    return maxpool2x2_raw(x)


def _up2(y: jax.Array) -> jax.Array:
    """Nearest-neighbour 2x upsample of NHWC."""
    return jnp.repeat(jnp.repeat(y, 2, axis=1), 2, axis=2)


def _pool_fwd(x):
    out = maxpool2x2_raw(x)
    return out, (x, out)


def _pool_bwd(res, g):
    x, out = res
    mask = (x == _up2(out)).astype(g.dtype)
    # Split gradient evenly among tied maxima within each window.
    counts = mask.reshape(
        x.shape[0], x.shape[1] // 2, 2, x.shape[2] // 2, 2, x.shape[3]
    ).sum(axis=(2, 4))
    dx = mask * _up2(g / jnp.maximum(counts, 1.0))
    return (dx,)


maxpool2x2.defvjp(_pool_fwd, _pool_bwd)
