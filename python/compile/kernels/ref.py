"""Pure-jnp/lax oracle for every L1 kernel — the correctness ground truth.

No Pallas anywhere in this file.  pytest/hypothesis sweeps assert
``kernels.* == ref.*`` (values and gradients) across shapes and dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def dense(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none") -> jax.Array:
    z = matmul(x, w) + b
    if act == "relu":
        z = jnp.maximum(z, 0.0)
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return z


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none") -> jax.Array:
    """SAME conv, stride 1, NHWC x HWIO -> NHWC via lax.conv_general_dilated."""
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + b
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def maxpool2x2(x: jax.Array) -> jax.Array:
    return lax.reduce_window(
        x.astype(jnp.float32),
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )
