"""Fused dense layer: Pallas matmul with bias + activation epilogue.

Fusing the epilogue into the matmul's final K step keeps the (bm, bn)
output tile in VMEM for the whole matmul->bias->activation chain — one HBM
write instead of three round trips (the TPU analogue of a CUDA epilogue
fusion).

``dense`` carries a custom VJP:
  da = g * act'(z)   (act' recovered from the *output*: relu' = out > 0)
  dx = da @ w.T      (Pallas matmul)
  dw = x.T @ da      (Pallas matmul)
  db = sum_rows(da)
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import (
    DEFAULT_BK,
    DEFAULT_BM,
    DEFAULT_BN,
    _ceil_to,
    _resolve_block,
    matmul_raw,
)

Activation = Literal["relu", "none"]


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        z = o_ref[...] + b_ref[...]
        if act == "relu":
            z = jnp.maximum(z, 0.0)
        o_ref[...] = z


def dense_raw(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: Activation = "none",
    bm: int | None = DEFAULT_BM,
    bn: int | None = DEFAULT_BN,
    bk: int | None = DEFAULT_BK,
) -> jax.Array:
    """act(x @ w + b) in one fused Pallas kernel (no VJP rule)."""
    m, kdim = x.shape
    k2, n = w.shape
    if kdim != k2 or b.shape != (n,):
        raise ValueError(f"dense shape mismatch: {x.shape} @ {w.shape} + {b.shape}")

    bm = _resolve_block(bm, m)
    bn = _resolve_block(bn, n)
    bk = _resolve_block(bk, kdim)

    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(kdim, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - kdim))) if (mp, kp) != (m, kdim) else x
    wp = jnp.pad(w, ((0, kp - kdim), (0, np_ - n))) if (kp, np_) != (kdim, n) else w
    bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b
    bp = bp.reshape(1, np_)

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_dense_kernel, nk=grid[2], act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32), wp.astype(jnp.float32), bp.astype(jnp.float32))
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jax.Array, w: jax.Array, b: jax.Array, act: Activation = "none"):
    """Differentiable fused dense layer act(x @ w + b)."""
    return dense_raw(x, w, b, act=act)


def _dense_fwd(x, w, b, act):
    out = dense_raw(x, w, b, act=act)
    return out, (x, w, out)


def _dense_bwd(act, res, g):
    x, w, out = res
    if act == "relu":
        da = g * (out > 0.0).astype(g.dtype)
    else:
        da = g
    dx = matmul_raw(da, w.T)
    dw = matmul_raw(x.T, da)
    db = jnp.sum(da, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
