"""L1 Pallas kernels for SFL-GA.

The compute hot-spot of the split CNN is matrix multiplication: the fc
layers directly, and the conv layers via im2col.  All matmuls route through
the blocked Pallas kernel in :mod:`matmul` (MXU-shaped tiles, VMEM-resident
blocks), with the bias+activation epilogue fused in :mod:`fused`.  Max
pooling has its own kernel in :mod:`pool`.

Every kernel is lowered with ``interpret=True`` — the CPU PJRT plugin used
at runtime cannot execute Mosaic custom-calls, so the interpret path is both
the correctness oracle target (vs :mod:`ref`) and the artifact path.  TPU
efficiency is estimated structurally (see DESIGN.md §Hardware-Adaptation).
"""

from . import matmul, fused, conv, pool, ref  # noqa: F401
