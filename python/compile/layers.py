"""Split-CNN architecture description: blocks, parameter specs, FLOPs.

The network is the McMahan-style CNN the paper trains (§V-A, [33]) plus one
extra fc128 block so that every cut v ∈ {1..4} moves parameters between the
client and the server:

    B1: conv5x5x32 + relu + maxpool2     B4: fc128 + relu
    B2: conv5x5x64 + relu + maxpool2     B5: fc10 (logits)
    B3: flatten + fc512 + relu

Cut v means the client owns blocks 1..v and uploads B_v's output (the
smashed data).  All FLOP counts are *per sample* and feed the paper's
computation-latency model (eqs 14-16) on the Rust side via the manifest.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv, fused, pool

NUM_BLOCKS = 5
NUM_CUTS = 4  # v in {1..4}


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    block: int  # 1-based block index owning this parameter

    @property
    def size(self) -> int:
        return math.prod(self.shape)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static description of one dataset's network."""

    name: str            # shape key, e.g. "28x28x1"
    height: int
    width: int
    channels: int
    classes: int = 10
    conv1: int = 32
    conv2: int = 64
    fc1: int = 512
    fc2: int = 128

    @property
    def input_shape(self) -> Tuple[int, int, int]:
        return (self.height, self.width, self.channels)

    @property
    def flat_after_conv(self) -> int:
        return (self.height // 4) * (self.width // 4) * self.conv2

    def param_specs(self) -> List[ParamSpec]:
        return [
            ParamSpec("conv1_w", (5, 5, self.channels, self.conv1), 1),
            ParamSpec("conv1_b", (self.conv1,), 1),
            ParamSpec("conv2_w", (5, 5, self.conv1, self.conv2), 2),
            ParamSpec("conv2_b", (self.conv2,), 2),
            ParamSpec("fc1_w", (self.flat_after_conv, self.fc1), 3),
            ParamSpec("fc1_b", (self.fc1,), 3),
            ParamSpec("fc2_w", (self.fc1, self.fc2), 4),
            ParamSpec("fc2_b", (self.fc2,), 4),
            ParamSpec("fc3_w", (self.fc2, self.classes), 5),
            ParamSpec("fc3_b", (self.classes,), 5),
        ]

    @property
    def total_params(self) -> int:
        return sum(p.size for p in self.param_specs())

    def client_param_count(self, cut: int) -> int:
        """Number of leading parameter arrays owned by the client at cut v."""
        return sum(1 for p in self.param_specs() if p.block <= cut)

    def phi(self, cut: int) -> int:
        """Client-side model size φ(v) in parameters (paper §II-A)."""
        return sum(p.size for p in self.param_specs() if p.block <= cut)

    def smashed_shape(self, cut: int, batch: int) -> Tuple[int, ...]:
        h2, w2 = self.height // 2, self.width // 2
        h4, w4 = self.height // 4, self.width // 4
        return {
            1: (batch, h2, w2, self.conv1),
            2: (batch, h4, w4, self.conv2),
            3: (batch, self.fc1),
            4: (batch, self.fc2),
        }[cut]

    # ---------------------------------------------------------- FLOPs
    def block_flops_fwd(self) -> List[int]:
        """Forward FLOPs per sample per block (2·MACs convention)."""
        h, w = self.height, self.width
        h2, w2 = h // 2, w // 2
        return [
            2 * 5 * 5 * self.channels * self.conv1 * h * w,
            2 * 5 * 5 * self.conv1 * self.conv2 * h2 * w2,
            2 * self.flat_after_conv * self.fc1,
            2 * self.fc1 * self.fc2,
            2 * self.fc2 * self.classes,
        ]

    def block_flops_bwd(self) -> List[int]:
        # Standard estimate: backward ≈ 2x forward (grad wrt inputs + weights).
        return [2 * f for f in self.block_flops_fwd()]

    def flops(self, cut: int) -> dict:
        """Per-sample FLOPs split at cut v: γ_F^c, γ_B^c, γ_F^s, γ_B^s."""
        fwd, bwd = self.block_flops_fwd(), self.block_flops_bwd()
        return {
            "client_fwd": sum(fwd[:cut]),
            "client_bwd": sum(bwd[:cut]),
            "server_fwd": sum(fwd[cut:]),
            "server_bwd": sum(bwd[cut:]),
        }


# Shape-keyed specs: mnist and fashion-mnist share "28x28x1".
SPECS = {
    "28x28x1": ModelSpec("28x28x1", 28, 28, 1),
    "32x32x3": ModelSpec("32x32x3", 32, 32, 3),
}

DATASET_SHAPE = {"mnist": "28x28x1", "fmnist": "28x28x1", "cifar10": "32x32x3"}


def init_params(spec: ModelSpec, key: jax.Array) -> List[jax.Array]:
    """He-normal weights, zero biases (matches rust data/init mirror)."""
    params: List[jax.Array] = []
    for p in spec.param_specs():
        key, sub = jax.random.split(key)
        if len(p.shape) == 1:
            params.append(jnp.zeros(p.shape, jnp.float32))
        else:
            fan_in = math.prod(p.shape[:-1])
            std = math.sqrt(2.0 / fan_in)
            params.append(std * jax.random.normal(sub, p.shape, jnp.float32))
    return params


# ------------------------------------------------------------- forward

def apply_block(spec: ModelSpec, idx: int, params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """Apply block `idx` (1-based); params = [w, b] for that block."""
    w, b = params
    if idx == 1 or idx == 2:
        x = conv.conv2d(x, w, b, act="relu")
        return pool.maxpool2x2(x)
    if idx == 3:
        x = x.reshape(x.shape[0], -1)
        return fused.dense(x, w, b, "relu")
    if idx == 4:
        return fused.dense(x, w, b, "relu")
    if idx == 5:
        return fused.dense(x, w, b, "none")
    raise ValueError(f"bad block index {idx}")


def forward_range(
    spec: ModelSpec,
    params: Sequence[jax.Array],
    x: jax.Array,
    first_block: int,
    last_block: int,
) -> jax.Array:
    """Apply blocks first..last inclusive; params are that range's arrays."""
    i = 0
    for blk in range(first_block, last_block + 1):
        x = apply_block(spec, blk, params[i : i + 2], x)
        i += 2
    return x


def apply_block_ref(spec: ModelSpec, idx: int, params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """XLA-native twin of `apply_block` (no Pallas).

    Used only by the *eval* artifact: evaluation is a measurement path, not
    the paper's training compute, and the big eval batch through the
    interpret-mode kernels would dominate wall time (DESIGN.md §Perf).
    The kernel tests prove `ref.* == kernels.*`, so swapping is exact.
    """
    from .kernels import ref

    w, b = params
    if idx == 1 or idx == 2:
        x = ref.conv2d(x, w, b, act="relu")
        return ref.maxpool2x2(x)
    if idx == 3:
        x = x.reshape(x.shape[0], -1)
        return ref.dense(x, w, b, "relu")
    if idx == 4:
        return ref.dense(x, w, b, "relu")
    if idx == 5:
        return ref.dense(x, w, b, "none")
    raise ValueError(f"bad block index {idx}")


def forward_range_ref(
    spec: ModelSpec,
    params: Sequence[jax.Array],
    x: jax.Array,
    first_block: int,
    last_block: int,
) -> jax.Array:
    """`forward_range` built on the XLA-native reference ops."""
    i = 0
    for blk in range(first_block, last_block + 1):
        x = apply_block_ref(spec, blk, params[i : i + 2], x)
        i += 2
    return x
