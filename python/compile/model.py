"""L2: the split CNN's five AOT roles (pure functions of arrays).

Every role is a pure JAX function over flat argument lists (no pytrees in
the signature beyond python lists, which flatten in order), so the lowered
HLO's parameter order is exactly the manifest's declared order and the Rust
runtime can feed buffers positionally:

  client_fwd(wc..., x)                -> (smashed,)
  server_grad(ws..., smashed, y1h)    -> (loss, g_ws..., g_smashed)
  client_grad(wc..., x, g_smashed)    -> (g_wc...,)
  full_grad(w..., x, y1h)             -> (loss, g_w...)
  eval_batch(w..., x, y1h)            -> (loss, correct_count)

`server_grad`'s `g_smashed` output is the per-client smashed-data gradient
s_t^n of eq (4); the SFL-GA aggregation s_t = Σ ρ^n s_t^n (eq 5) happens in
the Rust coordinator, which then feeds the *same* aggregated tensor to every
client's `client_grad` (the paper's broadcast step).  Traditional SFL/PSL
feed each client its own s_t^n through the identical artifact — the scheme
difference lives entirely in L3, as in the paper.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from . import layers
from .layers import NUM_BLOCKS, ModelSpec, forward_range


def cross_entropy(logits: jax.Array, y1h: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy with one-hot labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y1h * logp, axis=-1))


def client_fwd(spec: ModelSpec, cut: int, wc: Sequence[jax.Array], x: jax.Array):
    """Smashed data S_t^n = ℓ(w^c; ξ^n) (eq 1)."""
    return (forward_range(spec, wc, x, 1, cut),)


def server_fwd(spec: ModelSpec, cut: int, ws: Sequence[jax.Array], smashed: jax.Array):
    return forward_range(spec, ws, smashed, cut + 1, NUM_BLOCKS)


def server_grad(
    spec: ModelSpec,
    cut: int,
    ws: Sequence[jax.Array],
    smashed: jax.Array,
    y1h: jax.Array,
):
    """Loss, server-side grads g^{s,n} (eq 3) and smashed grads s_t^n (eq 4)."""

    def loss_fn(ws_, smashed_):
        return cross_entropy(server_fwd(spec, cut, ws_, smashed_), y1h)

    loss, (g_ws, g_smashed) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
        list(ws), smashed
    )
    return (loss, *g_ws, g_smashed)


def client_grad(
    spec: ModelSpec,
    cut: int,
    wc: Sequence[jax.Array],
    x: jax.Array,
    g_smashed: jax.Array,
):
    """Client-side grads g^c via VJP with the (aggregated) smashed-data
    gradient injected as the cotangent — eq (6)'s client half."""

    def fwd(wc_):
        return forward_range(spec, wc_, x, 1, cut)

    _, vjp = jax.vjp(fwd, list(wc))
    (g_wc,) = vjp(g_smashed)
    return tuple(g_wc)


def full_grad(spec: ModelSpec, w: Sequence[jax.Array], x: jax.Array, y1h: jax.Array):
    """FL baseline: loss + gradient of the complete model."""

    def loss_fn(w_):
        return cross_entropy(forward_range(spec, w_, x, 1, NUM_BLOCKS), y1h)

    loss, g_w = jax.value_and_grad(loss_fn)(list(w))
    return (loss, *g_w)


def eval_batch(spec: ModelSpec, w: Sequence[jax.Array], x: jax.Array, y1h: jax.Array):
    """Mean loss + correct-prediction count (f32) on one eval batch.

    Uses the XLA-native forward (`forward_range_ref`) — evaluation is a
    measurement path; the Pallas kernels stay on the training hot path.
    Exactness is covered by the kernel-vs-ref test suite."""
    logits = layers.forward_range_ref(spec, w, x, 1, NUM_BLOCKS)
    loss = cross_entropy(logits, y1h)
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y1h, axis=-1)).astype(jnp.float32)
    )
    return (loss, correct)


# --------------------------------------------------------- role builders

def make_role(spec: ModelSpec, role: str, cut: int, batch: int):
    """Return (fn, example_args) for jax.jit(fn).lower(*example_args).

    The returned fn takes *flat* positional array arguments in manifest
    order.  `cut` is ignored for full_grad/eval.
    """
    f32 = jnp.float32
    specs = spec.param_specs()
    n_client = spec.client_param_count(cut) if cut else 0
    x_shape = (batch, *spec.input_shape)
    y_shape = (batch, spec.classes)
    smashed = spec.smashed_shape(cut, batch) if cut else None

    def arg(shape):
        return jax.ShapeDtypeStruct(shape, f32)

    if role == "client_fwd":
        wc_shapes = [p.shape for p in specs[:n_client]]

        def fn(*args):
            wc, x = list(args[:n_client]), args[n_client]
            return client_fwd(spec, cut, wc, x)

        return fn, [arg(s) for s in wc_shapes] + [arg(x_shape)]

    if role == "server_grad":
        ws_shapes = [p.shape for p in specs[n_client:]]
        n_server = len(ws_shapes)

        def fn(*args):
            ws = list(args[:n_server])
            smashed_, y1h = args[n_server], args[n_server + 1]
            return server_grad(spec, cut, ws, smashed_, y1h)

        return fn, [arg(s) for s in ws_shapes] + [arg(smashed), arg(y_shape)]

    if role == "client_grad":
        wc_shapes = [p.shape for p in specs[:n_client]]

        def fn(*args):
            wc = list(args[:n_client])
            x, gs = args[n_client], args[n_client + 1]
            return client_grad(spec, cut, wc, x, gs)

        return fn, [arg(s) for s in wc_shapes] + [arg(x_shape), arg(smashed)]

    if role == "full_grad":
        all_shapes = [p.shape for p in specs]
        n_all = len(all_shapes)

        def fn(*args):
            w = list(args[:n_all])
            return full_grad(spec, w, args[n_all], args[n_all + 1])

        return fn, [arg(s) for s in all_shapes] + [arg(x_shape), arg(y_shape)]

    if role == "eval":
        all_shapes = [p.shape for p in specs]
        n_all = len(all_shapes)

        def fn(*args):
            w = list(args[:n_all])
            return eval_batch(spec, w, args[n_all], args[n_all + 1])

        return fn, [arg(s) for s in all_shapes] + [arg(x_shape), arg(y_shape)]

    raise ValueError(f"unknown role {role!r}")
